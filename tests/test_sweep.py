"""Vectorized sweep API (repro.api.sweep): Sweep / RunSet / Session.sweep.

The load-bearing claims:

  * every RunSet member is BIT-identical to the corresponding standalone
    ``Session.run`` -- on the batched host paths (vmap / pallas, where a
    (lambda x seed) grid runs as ONE vmapped chunk program), on the
    batched mesh path (vmap INSIDE shard_map, both sync lowerings), and
    through the batched state-carry executors (compressed / accelerated
    groups) alike, histories included;
  * lambda is a runtime executor input: a lambda grid costs ONE executor
    build (cache stats), and sessions compiled at different lambdas share
    one jit program;
  * ``continuation=True`` produces a valid warm-started regularization
    path (monotone ||w|| in lambda, members reproducible standalone);
  * grid vs zip shapes, ``history_every`` decimation (final entry always
    kept), ``RunSet.best``/``to_dict``;
  * ``fit_C`` inverts eq. (11) exactly and ``DelayModel(C="auto")``
    calibrates from a pilot run at compile time;
  * the ``solve()`` one-shot forwards ``warm_start=`` and ``straggler=``.
"""
import json

import jax
import numpy as np
import pytest

from repro.api import (
    DelayModel, Problem, Schedule, Session, Sweep, Topology, solve, sweep)
from repro.core.delay import StragglerModel, fit_C
from repro.core.engine.host import executor_cache_stats
from repro.data.synthetic import gaussian_regression
from repro.runtime.straggler import StragglerPolicy

LAM = 0.1


def _star():
    return Topology.star(4, 40, rounds=5, local_steps=40)


def _small_star():
    return Topology.star(3, 16, rounds=3, local_steps=12)


def _problem(topo, d=8):
    X, y = gaussian_regression(m=topo.m_total, d=d)
    return Problem(X, y, loss="squared", lam=LAM)


# ---------------------------------------------------------------------------
# bit-identity of members vs standalone runs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["vmap", "pallas"])
def test_sweep_members_bit_identical_to_single_runs(backend):
    """The fused (vmapped) lambda x seed batch reproduces each standalone
    run bit for bit -- iterates, history, and RNG-chain state."""
    topo = _small_star() if backend == "pallas" else _star()
    prob = _problem(topo)
    X, y = prob.X, prob.y
    sess = Session.compile(prob, topo, backend=backend)

    rs = sess.sweep(lams=[0.03, 0.1, 0.5], seeds=[0, 7])
    assert len(rs) == 6 and rs.shape == (3, 2)
    for pt in rs.points:
        single = Session.compile(
            Problem(X, y, lam=pt.lam), topo, backend=backend).run(
            key=jax.random.PRNGKey(pt.seed))
        mem = rs[pt.index]
        np.testing.assert_array_equal(np.asarray(mem.alpha),
                                      np.asarray(single.alpha))
        np.testing.assert_array_equal(np.asarray(mem.w),
                                      np.asarray(single.w))
        assert [h["gap"] for h in mem.history] == \
            [h["gap"] for h in single.history]
        assert [h["time"] for h in mem.history] == \
            [h["time"] for h in single.history]
        np.testing.assert_array_equal(np.asarray(mem.next_key),
                                      np.asarray(single.next_key))


@pytest.mark.parametrize("sync", ["psum", "reduce_scatter"])
def test_sweep_mesh_backend_members_match(sync):
    """The mesh path fuses the whole (lambda x seed) grid into ONE
    batched device program (vmap inside shard_map) and stays bit-identical
    to standalone mesh runs -- iterates, histories, AND the RNG chain --
    under both sync lowerings."""
    n = len(jax.devices())
    topo = Topology.star(n, 128 // n, rounds=4, local_steps=24)
    X, y = gaussian_regression(m=128, d=8)
    sess = Session.compile(Problem(X, y, lam=LAM), topo, backend="mesh",
                           mesh_sync=sync)
    rs = sess.sweep(lams=[0.05, 0.4], seeds=[0, 3])
    for pt in rs.points:
        single = Session.compile(
            Problem(X, y, lam=pt.lam), topo, backend="mesh",
            mesh_sync=sync).run(key=jax.random.PRNGKey(pt.seed))
        mem = rs[pt.index]
        np.testing.assert_array_equal(np.asarray(mem.alpha),
                                      np.asarray(single.alpha))
        np.testing.assert_array_equal(np.asarray(mem.w),
                                      np.asarray(single.w))
        np.testing.assert_array_equal(np.asarray(mem.next_key),
                                      np.asarray(single.next_key))
        assert [h["gap"] for h in mem.history] == \
            [h["gap"] for h in single.history]


def test_sweep_schedule_axis_produces_distinct_plans():
    """A schedules axis changes the plan per group; lambda x seed within
    each group still fuses, and the batched history pads ragged round
    counts with NaN."""
    topo = _star()
    prob = _problem(topo)
    sess = Session.compile(prob, topo)
    scheds = [Schedule(rounds=3, local_steps=10),
              Schedule(rounds=6, local_steps=20)]
    rs = sess.sweep(schedules=scheds, lams=[0.05, 0.5])
    assert len(rs) == 4 and rs.shape == (2, 2)
    assert rs.gaps.shape == (4, 7)            # padded to max T+1
    # group 0 ran 3 rounds -> entries 0..3 then NaN padding
    assert np.isfinite(rs.gaps[0, :4]).all()
    assert np.isnan(rs.gaps[0, 4:]).all()
    assert np.isfinite(rs.gaps[2]).all()
    for pt in rs.points:
        single = Session.compile(
            Problem(prob.X, prob.y, lam=pt.lam), topo,
            scheds[pt.schedule]).run(key=jax.random.PRNGKey(0))
        np.testing.assert_array_equal(np.asarray(rs[pt.index].alpha),
                                      np.asarray(single.alpha))


# ---------------------------------------------------------------------------
# lambda as a runtime input: executor-cache economics
# ---------------------------------------------------------------------------
def test_one_compile_per_plan_across_lambda_grid():
    """A lambda grid costs ONE batched-executor build; re-sweeping with
    different lambdas (and compiling sessions at different lambdas) is
    all cache hits."""
    topo = Topology.star(3, 30, rounds=4, local_steps=30)
    X, y = gaussian_regression(m=90, d=6)
    s1 = Session.compile(Problem(X, y, lam=0.05), topo)
    s2 = Session.compile(Problem(X, y, lam=0.8), topo)
    assert s1._fn is s2._fn, "lambda leaked into the executor cache key"

    before = executor_cache_stats()
    s1.sweep(lams=[0.01, 0.1, 1.0, 10.0], record_history=False)
    mid = executor_cache_stats()
    assert mid["misses"] == before["misses"] + 1   # the batched flavor
    s2.sweep(lams=[0.02, 0.2, 2.0], record_history=False)
    after = executor_cache_stats()
    assert after["misses"] == mid["misses"], \
        "second lambda grid rebuilt an executor"
    assert after["hits"] > mid["hits"]


def test_batched_carry_state_executor_matches_flat_batched():
    """The batched + carry_state StateExecutor (the fused-async building
    block) chunks bit-identically to the flat batched executor under
    all-ones masks: init -> step^T -> finalize == T flat steps."""
    import jax.numpy as jnp

    from repro.core.engine import host as host_mod
    from repro.core.engine import plan as plan_mod
    topo = Topology.star(3, 16, rounds=4, local_steps=12)
    prob = _problem(topo, d=6)
    X, y = prob.X, prob.y
    sess = Session.compile(prob, topo)
    plan = sess.plan
    lams = [0.05, 0.5]
    B, T = len(lams), 4
    keys = jnp.asarray(np.stack([
        plan_mod.chunked_key_plan(sess.resolved.chunk_tree, plan,
                                  plan_mod._raw_key(jax.random.PRNGKey(s)),
                                  T)
        for s in range(B)]))
    part = jnp.asarray(plan_mod.full_participation(plan))
    steps = jnp.asarray(np.broadcast_to(
        plan_mod.full_steps(plan)[None],
        (B, plan.n_ticks, plan.n_leaves, plan.h_max)))
    lms = jnp.stack([host_mod.regularizer_scale(l, prob.m, X.dtype)
                     for l in lams])
    a0 = jnp.zeros((B, prob.m), X.dtype)
    w0 = jnp.zeros((B, prob.d), X.dtype)

    flat = host_mod.get_host_executor(plan, loss=prob.loss,
                                      record_history=False, batched=True)
    a, w = a0, w0
    for t in range(T):
        a, w = flat(X, y, keys[:, t], a, w, part, steps, lms)

    se = host_mod.get_host_executor(plan, loss=prob.loss,
                                    record_history=False, batched=True,
                                    carry_state=True)
    state = se.init(X, a0, w0)
    for t in range(T):
        state = se.step(X, y, keys[:, t], state, part, steps, lms)
    a_s, w_s = se.finalize(state)
    np.testing.assert_array_equal(np.asarray(a_s), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(w_s), np.asarray(w))


# ---------------------------------------------------------------------------
# the schedule as a runtime input: local_h / h_cap / the sweep H axis
# ---------------------------------------------------------------------------
def test_run_local_h_full_capacity_bit_identical_to_static():
    """run(local_h=<the compiled H>) is bit-identical to the plain run:
    the step mask multiplies the static gates by exactly 1.0."""
    topo = _star()                      # local_steps=40
    prob = _problem(topo)
    sess = Session.compile(prob, topo)
    key = jax.random.PRNGKey(4)
    plain = sess.run(key=key)
    masked = sess.run(key=key, local_h=40)
    np.testing.assert_array_equal(np.asarray(plain.alpha),
                                  np.asarray(masked.alpha))
    np.testing.assert_array_equal(np.asarray(plain.w),
                                  np.asarray(masked.w))
    assert [h["gap"] for h in plain.history] == \
        [h["gap"] for h in masked.history]


def test_h_cap_runtime_h_zero_retrace():
    """A Schedule(h_cap=...) session executes MANY distinct H values --
    including per-leaf heterogeneous ones -- against ONE cached executor
    (no new executor builds, distinct iterates per H)."""
    topo = Topology.star(3, 16, rounds=3, local_steps=8)
    prob = _problem(topo, d=6)
    sess = Session.compile(prob, topo, Schedule(h_cap=16))
    assert sess.resolved.runtime_h == (8, 8, 8)
    assert sess.plan.h_max == 16        # compiled capacity
    key = jax.random.PRNGKey(0)
    r_def = sess.run(key=key, record_history=False)     # runtime H = 8
    before = executor_cache_stats()
    r4 = sess.run(key=key, local_h=4, record_history=False)
    r16 = sess.run(key=key, local_h=16, record_history=False)
    rhet = sess.run(key=key, local_h=[1, 8, 16], record_history=False)
    after = executor_cache_stats()
    assert after["misses"] == before["misses"], \
        "a runtime-H change rebuilt an executor"
    outs = [np.asarray(r.alpha) for r in (r_def, r4, r16, rhet)]
    for i in range(len(outs)):
        for j in range(i + 1, len(outs)):
            assert not np.array_equal(outs[i], outs[j]), (i, j)
    with pytest.raises(ValueError, match="h_cap"):
        Session.compile(prob, topo, Schedule(local_steps=32, h_cap=16))


def test_schedule_heterogeneous_local_steps():
    """Static per-leaf H specs: {name: H} dicts and left-to-right
    sequences resolve onto the tree leaves; bad specs are rejected."""
    topo = Topology.star(3, 16, rounds=3, local_steps=8)
    r = Schedule(local_steps={"W0": 4, "W2": 12}).resolve(topo)
    assert [l.rounds for l in r.chunk_tree.leaves()] == [4, 8, 12]
    r2 = Schedule(local_steps=[4, 8, 12]).resolve(topo)
    assert [l.rounds for l in r2.chunk_tree.leaves()] == [4, 8, 12]
    with pytest.raises(ValueError, match="unknown leaves"):
        Schedule(local_steps={"nope": 3}).resolve(topo)
    with pytest.raises(ValueError, match="left-to-right"):
        Schedule(local_steps=[1, 2]).resolve(topo)
    # heterogeneous plans execute (host backends)
    prob = _problem(topo, d=6)
    res = Session.compile(prob, topo, Schedule(local_steps=[4, 8, 12])).run(
        record_history=False)
    assert np.isfinite(np.asarray(res.alpha)).all()


@pytest.mark.parametrize("backend", ["vmap", "pallas"])
def test_sweep_local_h_axis_batched_and_bit_identical(backend):
    """An H axis batches over the step-mask operand in the SAME vmapped
    dispatch as lambda: members are bit-identical to standalone runs and
    the whole (lambda x H) grid reuses one executor."""
    topo = Topology.star(3, 16, rounds=4, local_steps=8)
    prob = _problem(topo, d=6)
    sess = Session.compile(prob, topo, Schedule(h_cap=32))
    rs = sess.sweep(lams=[0.05, 0.5], local_hs=[2, 8, 32])
    assert rs.shape == (2, 3) and len(rs) == 6
    for pt in rs.points:
        single = sess.run(key=jax.random.PRNGKey(0), lam=pt.lam,
                          local_h=pt.local_h)
        mem = rs[pt.index]
        np.testing.assert_array_equal(np.asarray(mem.alpha),
                                      np.asarray(single.alpha))
        np.testing.assert_array_equal(np.asarray(mem.w),
                                      np.asarray(single.w))
        assert [h["gap"] for h in mem.history] == \
            [h["gap"] for h in single.history]
    # distinct H values produce distinct members at fixed lambda
    assert not np.array_equal(np.asarray(rs.alphas[0]),
                              np.asarray(rs.alphas[1]))
    # a second H grid through the same session: zero new executor builds
    before = executor_cache_stats()
    sess.sweep(lams=[0.1], local_hs=[3, 5, 7], record_history=False)
    after = executor_cache_stats()
    assert after["misses"] == before["misses"]
    # config serialization carries the H axis
    blob = rs.to_dict()
    assert blob["configs"][0]["local_h"] == 2


def test_run_local_h_per_slot_spec():
    """Per-slot (S, n) runtime schedules execute end-to-end (regression:
    the simulated-clock path used to crash on 2-D specs)."""
    topo = Topology.two_level(2, 2, 16, root_rounds=3, group_rounds=2,
                              local_steps=8)
    prob = _problem(topo, d=6)
    sess = Session.compile(prob, topo)
    S = sess.plan.n_ticks
    spec = np.tile(np.array([[2, 4, 6, 8]]), (S, 1))
    res = sess.run(key=jax.random.PRNGKey(0), local_h=spec)
    same = sess.run(key=jax.random.PRNGKey(0), local_h=[2, 4, 6, 8])
    np.testing.assert_array_equal(np.asarray(res.alpha),
                                  np.asarray(same.alpha))
    assert [h["time"] for h in res.history] == \
        [h["time"] for h in same.history]
    varied = spec.copy()
    varied[0] = 1                       # genuinely per-slot schedule
    res2 = sess.run(key=jax.random.PRNGKey(0), local_h=varied,
                    record_history=False)
    assert not np.array_equal(np.asarray(res2.alpha),
                              np.asarray(res.alpha))


def test_auto_h_cap_bounds_planner_search():
    """Regression: rounds='auto' + h_cap optimizes UNDER the capacity --
    level_plan, round times, and the root budget all describe the H the
    program actually executes (no post-hoc clamp drift)."""
    topo = Topology.star(3, 300, t_lp=4e-5, t_cp=3e-5, t_delay=4e-2)
    free = Schedule.auto(t_total=1.0, C=0.5, h_max=10**7).resolve(topo)
    assert free.chunk_tree.leaves()[0].rounds > 64  # unconstrained H*
    capped = Schedule.auto(t_total=1.0, C=0.5, h_max=10**7,
                           h_cap=64).resolve(topo)
    assert capped.level_plan[0]["H"] == capped.runtime_h[0] <= 64
    rt = capped.level_plan[-1]["round_time"]
    assert capped.rounds == max(1, int(1.0 / rt))
    assert capped.per_round_time == \
        pytest.approx(capped.round_time_for(capped.runtime_h))


def test_sweep_local_h_zip_mode():
    topo = Topology.star(3, 16, rounds=2, local_steps=8)
    sess = Session.compile(_problem(topo, d=6), topo, Schedule(h_cap=8))
    rz = sess.sweep(lams=[0.1, 0.2], local_hs=[2, 8], mode="zip",
                    record_history=False)
    assert rz.shape == (2,)
    assert [(p.lam, p.local_h) for p in rz.points] == [(0.1, 2), (0.2, 8)]
    with pytest.raises(ValueError, match="equal-length"):
        sess.sweep(lams=[0.1], local_hs=[2, 8], mode="zip")


# ---------------------------------------------------------------------------
# continuation paths
# ---------------------------------------------------------------------------
def test_continuation_path_monotone_and_reproducible():
    """Warm-started regularization path: ||w|| grows as lambda shrinks
    (members near the closed-form ridge solutions), and each member
    reproduces as a standalone warm-started run (the primal is rebuilt
    under the new lambda: w = X^T alpha / (lam m))."""
    from repro.core.dual import w_of_alpha
    topo = Topology.star(4, 40, rounds=40, local_steps=60)
    prob = _problem(topo)
    X = prob.X
    lams = [3.0, 1.0, 0.3, 0.1, 0.03]
    sess = Session.compile(prob, topo)
    rs = sess.sweep(lams=lams, continuation=True, record_history=False)
    norms = [float(np.linalg.norm(np.asarray(rs[i].w)))
             for i in range(len(lams))]
    assert all(b > a for a, b in zip(norms, norms[1:], strict=False)), norms

    # member i == standalone run warm-started from member i-1's dual
    prev = rs[1]
    single = sess.run(key=jax.random.PRNGKey(0), lam=lams[2],
                      warm_start=(prev.alpha,
                                  w_of_alpha(prev.alpha, X, lams[2])),
                      record_history=False)
    np.testing.assert_array_equal(np.asarray(rs[2].alpha),
                                  np.asarray(single.alpha))
    np.testing.assert_array_equal(np.asarray(rs[2].w),
                                  np.asarray(single.w))

    # the requested (unsorted) order is preserved in the RunSet
    shuffled = [0.1, 3.0, 0.3]
    rs2 = sess.sweep(lams=shuffled, continuation=True, rounds=5,
                     record_history=False)
    assert [pt.lam for pt in rs2.points] == shuffled


def test_warm_start_across_lambda_rebuilds_primal():
    """Regression: warm-starting a run under a DIFFERENT lambda must
    rebuild w = X^T alpha / (lam m) -- carrying the old primal breaks the
    eq.-(13) invariant and converges to wrong iterates.  Same-lambda
    warm starts stay bit-exact continuations."""
    from repro.core.dual import w_of_alpha
    topo = Topology.star(4, 40, rounds=30, local_steps=60)
    prob = _problem(topo)
    X = prob.X
    sess = Session.compile(prob, topo)
    key = jax.random.PRNGKey(0)

    r1 = sess.run(key=key, lam=1.0, record_history=False)
    assert r1.lam == 1.0
    r2 = sess.run(key=key, lam=0.01, warm_start=r1, record_history=False)
    # invariant holds at the end of the cross-lambda continuation
    w_inv = w_of_alpha(r2.alpha, X, 0.01)
    np.testing.assert_allclose(np.asarray(r2.w), np.asarray(w_inv),
                               rtol=1e-4, atol=1e-6)
    # and it equals the explicitly-rebuilt warm start bit for bit
    manual = sess.run(key=key, lam=0.01,
                      warm_start=(r1.alpha, w_of_alpha(r1.alpha, X, 0.01)),
                      record_history=False)
    np.testing.assert_array_equal(np.asarray(r2.alpha),
                                  np.asarray(manual.alpha))
    np.testing.assert_array_equal(np.asarray(r2.w), np.asarray(manual.w))

    # same-lambda warm starts are untouched: exact split == one long run
    once = sess.run(rounds=8, key=key, record_history=False)
    first = sess.run(rounds=3, key=key, record_history=False)
    rest = sess.run(rounds=5, warm_start=first, record_history=False)
    np.testing.assert_array_equal(np.asarray(rest.alpha),
                                  np.asarray(once.alpha))


def test_continuation_validation():
    with pytest.raises(ValueError, match="lams"):
        Sweep(seeds=[0, 1], continuation=True)
    with pytest.raises(ValueError, match="grid"):
        Sweep(lams=[1.0, 0.1], mode="zip", continuation=True,
              seeds=[0, 1])


# ---------------------------------------------------------------------------
# grid vs zip shapes
# ---------------------------------------------------------------------------
def test_grid_vs_zip_shapes():
    topo = _star()
    sess = Session.compile(_problem(topo), topo)
    rs = sess.sweep(lams=[0.1, 0.2, 0.3], seeds=[0, 1], rounds=2,
                    record_history=False)
    assert rs.shape == (3, 2) and len(rs) == 6
    # grid order: lams outer, seeds inner
    assert [(p.lam, p.seed) for p in rs.points[:2]] == \
        [(0.1, 0), (0.1, 1)]

    rz = sess.sweep(lams=[0.1, 0.2, 0.3], seeds=[5, 6, 7], mode="zip",
                    rounds=2, record_history=False)
    assert rz.shape == (3,) and len(rz) == 3
    assert [(p.lam, p.seed) for p in rz.points] == \
        [(0.1, 5), (0.2, 6), (0.3, 7)]

    with pytest.raises(ValueError, match="equal-length"):
        sess.sweep(lams=[0.1, 0.2], seeds=[0, 1, 2], mode="zip")
    with pytest.raises(ValueError, match="at least one axis"):
        Sweep()
    with pytest.raises(ValueError, match="non-empty"):
        Sweep(lams=[])
    with pytest.raises(ValueError, match="grid.*zip|zip.*grid|mode"):
        Sweep(lams=[0.1], mode="diagonal")


# ---------------------------------------------------------------------------
# history_every decimation
# ---------------------------------------------------------------------------
def test_history_every_keeps_final_entry():
    """run(history_every=k) records rounds {0, k, 2k, ...} AND the final
    round; recorded entries are bitwise those of the full history."""
    topo = _star()
    sess = Session.compile(_problem(topo), topo)
    key = jax.random.PRNGKey(2)
    full = sess.run(rounds=7, key=key)
    dec = sess.run(rounds=7, key=key, history_every=3)
    assert [h["round"] for h in dec.history] == [0, 3, 6, 7]
    by_round = {h["round"]: h for h in full.history}
    for h in dec.history:
        assert h == by_round[h["round"]]
    np.testing.assert_array_equal(np.asarray(dec.alpha),
                                  np.asarray(full.alpha))
    with pytest.raises(ValueError, match="history_every"):
        sess.run(rounds=2, history_every=0)


def test_history_every_threads_through_sweep():
    topo = _star()
    sess = Session.compile(_problem(topo), topo)
    rs = sess.sweep(lams=[0.05, 0.5], rounds=7, history_every=3)
    for i in range(len(rs)):
        assert [h["round"] for h in rs[i].history] == [0, 3, 6, 7]
    assert rs.gaps.shape == (2, 4)


# ---------------------------------------------------------------------------
# RunSet accessors and serialization
# ---------------------------------------------------------------------------
def test_runset_best_and_to_dict():
    topo = _star()
    prob = _problem(topo)
    sess = Session.compile(prob, topo)
    rs = sess.sweep(lams=[0.02, 0.2, 2.0], seeds=[0, 1])
    finals = rs.final("gap")
    assert np.isfinite(finals).all()
    bi = rs.best_index("gap")
    assert finals[bi] == finals.min()
    assert rs.best("gap").gaps[-1] == finals[bi]
    # dual is maximized
    assert rs.final("dual")[rs.best_index("dual")] == rs.final("dual").max()

    d = rs.to_dict()
    blob = json.loads(json.dumps(d))
    assert blob["shape"] == [3, 2]
    assert len(blob["configs"]) == 6
    assert blob["configs"][0] == {"lam": 0.02, "seed": 0, "schedule": None,
                                  "local_h": None}
    assert np.asarray(blob["alphas"]).shape == (6, prob.m)
    assert blob["final_gap"][bi] == pytest.approx(float(finals[bi]))

    # record_history=False still serializes (no history block)
    rs2 = sess.sweep(lams=[0.1], rounds=1, record_history=False)
    assert "history" not in rs2.to_dict()
    with pytest.raises(ValueError, match="record_history"):
        rs2.gaps


# ---------------------------------------------------------------------------
# fit_C / DelayModel(C="auto")
# ---------------------------------------------------------------------------
def test_fit_c_inverts_eq11_exactly():
    K, H, delta, C_true = 4, 64, 1 / 32, 0.7
    g = 1 - (1 - (1 - delta) ** H) * C_true / K
    gaps = [2.5 * g ** t for t in range(10)]
    assert fit_C(gaps, K=K, H=H, delta=delta) == pytest.approx(C_true)
    # accepts history-dict lists and clips into (0, K]
    hist = [{"gap": g_} for g_ in gaps]
    assert fit_C(hist, K=K, H=H, delta=delta) == pytest.approx(C_true)
    assert fit_C([1.0, 1e-9], K=4, H=64, delta=delta) <= 4.0
    assert fit_C([1.0, 2.0, 4.0], K=4, H=64, delta=delta) > 0  # divergent
    with pytest.raises(ValueError, match="two"):
        fit_C([1.0], K=4, H=64, delta=delta)


def test_delay_model_auto_c_calibrates_from_pilot():
    topo = Topology.star(3, 64, rounds=8, local_steps=32, t_lp=1e-5,
                         t_delay=1e-3)
    X, y = gaussian_regression(m=topo.m_total, d=12)
    prob = Problem.ridge(X, y, lam=0.05)
    sched = Schedule.auto(t_total=0.5, C="auto", pilot_rounds=6,
                          h_max=10**4)
    sess = Session.compile(prob, topo, sched)
    assert sess.fitted_C is not None and 0 < sess.fitted_C <= 3
    assert sess.level_plan is not None
    res = sess.run()
    assert np.isfinite(res.gaps).all()
    # a fixed-C schedule leaves fitted_C unset
    assert Session.compile(prob, topo).fitted_C is None


def test_auto_c_hierarchical_clips_to_smallest_level():
    """Regression: the fitted C is clipped to the SMALLEST sync-level
    group size (the planner checks C against every level's K), so fast
    pilots on wide-rooted two-level trees still compile."""
    topo = Topology.two_level(8, 2, 8, root_rounds=6, group_rounds=2,
                              local_steps=16, t_lp=4e-5, root_delay=1e-3,
                              group_delay=1e-4)
    X, y = gaussian_regression(m=topo.m_total, d=6)
    prob = Problem.ridge(X, y, lam=1.0)        # contracts fast
    sess = Session.compile(prob, topo,
                           Schedule.auto(t_total=0.3, C="auto",
                                         pilot_rounds=5, h_max=10**3))
    assert 0 < sess.fitted_C <= 2               # inner group size, not 8


def test_auto_c_skipped_for_explicit_rounds():
    """Regression: an explicit-rounds schedule never reads the
    DelayModel, so C='auto' must not pay a pilot run or set fitted_C."""
    topo = Topology.star(3, 16, rounds=4, local_steps=8, t_lp=1e-5,
                         t_delay=1e-3)
    X, y = gaussian_regression(m=48, d=4)
    sess = Session.compile(
        Problem(X, y, lam=LAM), topo,
        Schedule(rounds=4, delay=DelayModel(t_total=1.0, C="auto")))
    assert sess.fitted_C is None


def test_delay_model_auto_c_validation():
    with pytest.raises(ValueError, match="auto"):
        DelayModel(t_total=1.0, C="bogus")
    with pytest.raises(ValueError, match="pilot_rounds"):
        DelayModel(t_total=1.0, C="auto", pilot_rounds=1)
    topo = Topology.star(3, 8, t_lp=1e-5, t_delay=1e-3)
    with pytest.raises(ValueError, match="Session.compile"):
        Schedule(rounds="auto",
                 delay=DelayModel(t_total=1.0, C="auto")).resolve(topo)


# ---------------------------------------------------------------------------
# solve() feature parity (bugfix regression)
# ---------------------------------------------------------------------------
def test_solve_forwards_warm_start_and_straggler():
    """Regression: the one-shot wrapper used to silently DROP warm_start=
    and straggler=."""
    topo = Topology.star(4, 32, rounds=6, local_steps=32, t_lp=1e-5,
                         t_delay=0.01)
    X, y = gaussian_regression(m=topo.m_total, d=8)
    prob = Problem.ridge(X, y, lam=LAM)
    sess = Session.compile(prob, topo)
    key = jax.random.PRNGKey(5)

    first = sess.run(rounds=3, key=key, record_history=False)
    direct = sess.run(rounds=5, warm_start=first, record_history=False)
    via = solve(prob, topo, rounds=5, warm_start=first,
                record_history=False)
    np.testing.assert_array_equal(np.asarray(via.alpha),
                                  np.asarray(direct.alpha))
    np.testing.assert_array_equal(np.asarray(via.w), np.asarray(direct.w))

    pol = StragglerPolicy(model=StragglerModel(slow_prob=0.3,
                                               slow_factor=30.0),
                          max_consecutive=2, seed=0)
    res = solve(prob, topo, rounds=6, straggler=pol)
    assert "participants" in res.history[-1]
    assert "time_sync" in res.history[-1]


def test_one_shot_sweep_matches_session_sweep():
    topo = _star()
    prob = _problem(topo)
    a = sweep(prob, topo, lams=[0.05, 0.5], rounds=3,
              record_history=False)
    b = Session.compile(prob, topo).sweep(lams=[0.05, 0.5], rounds=3,
                                          record_history=False)
    np.testing.assert_array_equal(np.asarray(a.alphas),
                                  np.asarray(b.alphas))
    with pytest.raises(ValueError, match="not both"):
        Session.compile(prob, topo).sweep(Sweep(lams=[0.1]), lams=[0.2])
    # the one-shot wrapper validates identically instead of silently
    # dropping inline axes (regression)
    with pytest.raises(ValueError, match="not both"):
        sweep(prob, topo, Sweep(lams=[0.1]), seeds=[0, 1])
    # mode=/continuation= alongside a spec are rejected too, not ignored
    with pytest.raises(ValueError, match="not both"):
        Session.compile(prob, topo).sweep(Sweep(lams=[0.1, 0.2]),
                                          continuation=True)
    with pytest.raises(ValueError, match="not both"):
        sweep(prob, topo, Sweep(lams=[0.1, 0.2]), mode="zip")


# ---------------------------------------------------------------------------
# fused stateful / accelerated / continuation groups
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["vmap", "mesh"])
def test_sweep_compressed_members_bit_identical(backend):
    """Compressed plans fuse too: the per-member EF residuals ride the
    batched state-carry executor, and every member stays bit-identical
    to its standalone compressed run (histories and RNG chain included)."""
    n = len(jax.devices())
    topo = (Topology.star(n, 128 // n, rounds=4, local_steps=16)
            if backend == "mesh" else _small_star())
    X, y = gaussian_regression(m=topo.m_total, d=8)
    prob = Problem(X, y, loss="squared", lam=LAM)
    sched = Schedule(compression="topk_0.25")
    sess = Session.compile(prob, topo, sched, backend=backend)
    rs = sess.sweep(lams=[0.05, 0.4], seeds=[0, 2])
    for pt in rs.points:
        single = Session.compile(
            Problem(X, y, loss="squared", lam=pt.lam), topo, sched,
            backend=backend).run(key=jax.random.PRNGKey(pt.seed))
        mem = rs[pt.index]
        np.testing.assert_array_equal(np.asarray(mem.alpha),
                                      np.asarray(single.alpha))
        np.testing.assert_array_equal(np.asarray(mem.w),
                                      np.asarray(single.w))
        np.testing.assert_array_equal(np.asarray(mem.next_key),
                                      np.asarray(single.next_key))
        assert [h["gap"] for h in mem.history] == \
            [h["gap"] for h in single.history]


@pytest.mark.parametrize("backend", ["vmap", "mesh"])
def test_sweep_accelerated_members_bit_identical(backend):
    """Accelerated (server-momentum) groups fuse through the same batched
    state carry: members match standalone accelerated runs bit for bit."""
    n = len(jax.devices())
    topo = (Topology.star(n, 128 // n, rounds=4, local_steps=16)
            if backend == "mesh" else _small_star())
    X, y = gaussian_regression(m=topo.m_total, d=8)
    prob = Problem(X, y, loss="squared", lam=LAM)
    sched = Schedule(acceleration=0.5)
    sess = Session.compile(prob, topo, sched, backend=backend)
    rs = sess.sweep(lams=[0.05, 0.4], seeds=[0, 2])
    for pt in rs.points:
        single = Session.compile(
            Problem(X, y, loss="squared", lam=pt.lam), topo, sched,
            backend=backend).run(key=jax.random.PRNGKey(pt.seed))
        mem = rs[pt.index]
        np.testing.assert_array_equal(np.asarray(mem.alpha),
                                      np.asarray(single.alpha))
        np.testing.assert_array_equal(np.asarray(mem.w),
                                      np.asarray(single.w))
        np.testing.assert_array_equal(np.asarray(mem.next_key),
                                      np.asarray(single.next_key))
        assert [h["gap"] for h in mem.history] == \
            [h["gap"] for h in single.history]


def test_continuation_with_seed_axis_fuses_per_stage():
    """A (lambda x seed) continuation grid runs ONE batched program per
    lambda stage; each seed's chain is an independent warm-started path,
    bit-identical to running that chain by hand."""
    from repro.core.dual import w_of_alpha
    topo = _star()
    prob = _problem(topo)
    X = prob.X
    lams, seeds = [1.0, 0.1], [0, 7]
    sess = Session.compile(prob, topo)
    rs = sess.sweep(lams=lams, seeds=seeds, continuation=True,
                    record_history=False)
    for seed in seeds:
        first = sess.run(key=jax.random.PRNGKey(seed), lam=lams[0],
                         record_history=False)
        second = sess.run(
            key=jax.random.PRNGKey(seed), lam=lams[1],
            warm_start=(first.alpha, w_of_alpha(first.alpha, X, lams[1])),
            record_history=False)
        by_pt = {(pt.lam, pt.seed): rs[pt.index] for pt in rs.points}
        np.testing.assert_array_equal(
            np.asarray(by_pt[(lams[0], seed)].alpha),
            np.asarray(first.alpha))
        np.testing.assert_array_equal(
            np.asarray(by_pt[(lams[1], seed)].alpha),
            np.asarray(second.alpha))
        np.testing.assert_array_equal(
            np.asarray(by_pt[(lams[1], seed)].w), np.asarray(second.w))


def test_sweep_fused_paths_bypass_sequential(monkeypatch):
    """Mesh, compressed, accelerated, and continuation sweeps all take the
    batched dispatch -- the per-member sequential fallback is reserved for
    checkpointed stateful fleets and must not be reached here."""
    import importlib
    sweep_mod = importlib.import_module("repro.api.sweep")

    def _boom(*args, **kwargs):                     # pragma: no cover
        raise AssertionError("sequential fallback must not run")

    monkeypatch.setattr(sweep_mod, "_run_group_sequential", _boom)
    topo = _small_star()
    prob = _problem(topo)
    n = len(jax.devices())
    mtopo = Topology.star(n, 64 // n, rounds=3, local_steps=8)
    mX, my = gaussian_regression(m=64, d=8)
    mprob = Problem(mX, my, loss="squared", lam=LAM)

    Session.compile(mprob, mtopo, backend="mesh").sweep(
        lams=[0.1, 0.3], record_history=False)
    Session.compile(prob, topo, Schedule(compression="int8")).sweep(
        lams=[0.1, 0.3], record_history=False)
    Session.compile(prob, topo, Schedule(acceleration=0.3)).sweep(
        lams=[0.1, 0.3], record_history=False)
    Session.compile(prob, topo).sweep(
        lams=[0.5, 0.1], continuation=True, record_history=False)
