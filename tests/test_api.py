"""Sessionized API (repro.api): Problem / Topology / Schedule / Session.

The load-bearing claims:

  * chunked Session execution is BIT-identical to the monolithic compiled
    program (and hence to the legacy entry points, which are now shims)
    on star / two-level / imbalanced trees for both host backends;
  * Topology serialization round-trips every tree shape we use;
  * ``Schedule(rounds="auto")`` reproduces the eq.-(12) planner's
    per-level H and beats a naive fixed schedule on simulated
    time-to-gap when links are slow;
  * executors are cache-hits after the first compile;
  * warm restarts continue the RNG chain exactly.
"""
import jax
import numpy as np
import pytest

from repro.api import DelayModel, Problem, Schedule, Session, Topology, solve
from repro.core import dual as D
from repro.core import engine
from repro.core.delay import FixedLevel, optimal_h, plan_hierarchical_h
from repro.core.engine.host import executor_cache_stats
from repro.core.engine.plan import compile_tree, key_plan
from repro.core.tree import TreeNode, star, two_level
from repro.data.synthetic import gaussian_regression

LAM = 0.1


def _imbalanced_topology() -> Topology:
    return Topology.groups(
        [[24, 16], [12, 20, 8], 20],
        root_rounds=5, group_rounds=2, local_steps=30)


TOPOLOGIES = {
    "star": lambda: Topology.star(4, 40, rounds=6, local_steps=80),
    "two_level": lambda: Topology.two_level(
        2, 2, 40, root_rounds=5, group_rounds=3, local_steps=60),
    "imbalanced": _imbalanced_topology,
}


# ---------------------------------------------------------------------------
# Session vs the monolithic program and the legacy shims
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["vmap", "pallas"])
@pytest.mark.parametrize("case", sorted(TOPOLOGIES))
def test_session_bit_identical_to_monolithic(case, backend):
    """Chunked (per-root-round) execution == ONE monolithic compiled run,
    bit for bit: the root-sync boundary is a complete carry."""
    topo = TOPOLOGIES[case]()
    X, y = gaussian_regression(m=topo.m_total, d=12)
    key = jax.random.PRNGKey(3)
    prob = Problem(X, y, loss="squared", lam=LAM)

    sess = Session.compile(prob, topo, backend=backend)
    res = sess.run(key=key, record_history=False)

    full = topo.tree
    plan = compile_tree(full)
    keys = key_plan(full, plan, key)
    alpha_m, w_m = engine.execute_plan(
        plan, X, y, keys, loss=prob.loss, lam=LAM, record_history=False,
        backend=backend)
    np.testing.assert_array_equal(np.asarray(res.alpha), np.asarray(alpha_m))
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(w_m))


@pytest.mark.parametrize("case", sorted(TOPOLOGIES))
def test_session_bit_identical_to_legacy_entry_point(case):
    """Acceptance: Session.compile + run == tree_dual_solve exactly."""
    from repro.core.treedual import tree_dual_solve
    topo = TOPOLOGIES[case]()
    X, y = gaussian_regression(m=topo.m_total, d=10)
    key = jax.random.PRNGKey(11)
    res = Session.compile(Problem(X, y, lam=LAM), topo).run(key=key)
    with pytest.deprecated_call():
        leg = tree_dual_solve(topo.tree, X, y, loss=D.squared, lam=LAM,
                              key=key)
    np.testing.assert_array_equal(np.asarray(res.alpha),
                                  np.asarray(leg.alpha))
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(leg.w))
    assert [h["gap"] for h in res.history] == \
        [h["gap"] for h in leg.history]


def test_session_mesh_backend_behind_one_surface():
    """backend='mesh' is reachable from Session.compile (auto-built mesh)
    and agrees with the host backend on the same schedule."""
    n = len(jax.devices())
    topo = Topology.star(n, 256 // n, rounds=8, local_steps=64)
    X, y = gaussian_regression(m=256, d=16)
    prob = Problem(X, y, lam=LAM)
    key = jax.random.PRNGKey(2)
    res_m = Session.compile(prob, topo, backend="mesh").run(
        key=key, record_history=False)
    res_h = Session.compile(prob, topo, backend="vmap").run(
        key=key, record_history=False)
    np.testing.assert_allclose(np.asarray(res_m.alpha),
                               np.asarray(res_h.alpha),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(res_m.w), np.asarray(res_h.w),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# warm restarts, streaming, cache
# ---------------------------------------------------------------------------
def test_warm_start_continuation_is_exact():
    """run(3) then run(5, warm_start=...) == run(8): state AND RNG chain
    are a complete carry."""
    topo = TOPOLOGIES["two_level"]()
    X, y = gaussian_regression(m=topo.m_total, d=8)
    sess = Session.compile(Problem(X, y, lam=LAM), topo)
    key = jax.random.PRNGKey(7)

    once = sess.run(rounds=8, key=key, record_history=False)
    first = sess.run(rounds=3, key=key, record_history=False)
    rest = sess.run(rounds=5, warm_start=first, record_history=False)
    np.testing.assert_array_equal(np.asarray(rest.alpha),
                                  np.asarray(once.alpha))
    np.testing.assert_array_equal(np.asarray(rest.w), np.asarray(once.w))

    # a plain (alpha, w) pair is accepted too (fresh RNG chain)
    pair = sess.run(rounds=2, warm_start=(first.alpha, first.w),
                    key=first.next_key, record_history=False)
    mid = sess.run(rounds=5, key=key, record_history=False)
    np.testing.assert_array_equal(np.asarray(pair.alpha),
                                  np.asarray(mid.alpha))


def test_history_streams_mid_run():
    topo = TOPOLOGIES["star"]()
    X, y = gaussian_regression(m=topo.m_total, d=8)
    sess = Session.compile(Problem(X, y, lam=LAM), topo)
    seen = []
    res = sess.run(rounds=4, on_round=seen.append)
    assert len(seen) == 5 and seen == res.history          # 0..4 inclusive
    assert [h["round"] for h in seen] == list(range(5))
    # gaps decrease overall and every entry was delivered incrementally
    assert seen[-1]["gap"] < seen[0]["gap"]


def test_executor_cache_hits_on_repeated_solves():
    """Satellite: repeated engine.solve / Session.compile on the same tree
    must reuse ONE jit/scan program (cache hits, no rebuilds)."""
    topo = Topology.star(3, 30, rounds=4, local_steps=50)
    X, y = gaussian_regression(m=90, d=6)
    prob = Problem(X, y, lam=0.07)

    s1 = Session.compile(prob, topo)
    before = executor_cache_stats()
    s2 = Session.compile(prob, topo)
    res1 = s1.run(record_history=False)
    res2 = s2.run(record_history=False)
    after = executor_cache_stats()
    assert after["misses"] == before["misses"], "executor was rebuilt"
    assert after["hits"] >= before["hits"] + 1
    assert s1._fn is s2._fn
    np.testing.assert_array_equal(np.asarray(res1.alpha),
                                  np.asarray(res2.alpha))

    # the legacy entry point rides the same cache
    before = executor_cache_stats()
    engine.solve(topo.tree, X, y, loss=prob.loss, lam=0.07,
                 record_history=False)
    after = executor_cache_stats()
    assert after["misses"] == before["misses"]


# ---------------------------------------------------------------------------
# Topology: builders + serialization round-trip
# ---------------------------------------------------------------------------
def _legacy_trees():
    la = TreeNode(name="A", rounds=40, data_size=24, t_lp=2e-5)
    lb = TreeNode(name="B", rounds=30, data_size=16)
    lc = TreeNode(name="C", rounds=50, data_size=8, up_delay=0.3)
    g = TreeNode(name="g", children=(lb, lc), rounds=2)
    mid = TreeNode(name="mid", children=(g, la), rounds=2, t_cp=1e-6)
    ld = TreeNode(name="Dd", rounds=20, data_size=12)
    return {
        "star": star(4, 60, outer_rounds=8, local_steps=120, t_lp=1e-5,
                     t_delay=0.4, t_cp=3e-5),
        "two_level": two_level(2, 2, 60, root_rounds=5, group_rounds=3,
                               local_steps=100, root_delay=1.0,
                               group_delay=1e-3),
        "imbalanced": TreeNode(name="root", children=(mid, ld), rounds=6),
    }


def test_topology_roundtrip_every_tree():
    trees = dict(_legacy_trees())
    trees["groups"] = _imbalanced_topology().tree
    trees["balanced"] = Topology.balanced(
        [2, 3], m_leaf=16, local_steps=32, level_rounds=[4, 2],
        level_delays=[0.5, 1e-3], t_lp=1e-5, t_cp=1e-6).tree
    for name, tree in trees.items():
        topo = Topology.from_tree(tree)
        assert Topology.from_dict(topo.to_dict()) == topo, name
        assert Topology.from_json(topo.to_json()) == topo, name
        # the round-trip preserves the *solver-relevant* lowering exactly
        assert compile_tree(Topology.from_json(topo.to_json()).tree
                            ).fingerprint == compile_tree(tree).fingerprint, \
            name


def test_topology_rejects_duplicate_leaves_and_leaf_root():
    leaf = TreeNode(name="x", rounds=1, data_size=4)
    with pytest.raises(ValueError):
        Topology.from_tree(leaf)
    with pytest.raises(ValueError):
        Topology.from_tree(TreeNode(name="r", children=(leaf, leaf)))


def test_topology_sync_levels_two_level():
    topo = Topology.two_level(3, 4, 16, root_delay=2.0, group_delay=0.25,
                              t_lp=1e-5)
    lv = topo.sync_levels()      # innermost first
    assert [l.group_size for l in lv] == [4, 3]
    assert [l.round_delay() for l in lv] == [0.25, 2.0]
    with pytest.raises(ValueError):
        _imbalanced_topology().sync_levels()


# ---------------------------------------------------------------------------
# Schedule: explicit overrides and the eq.-(12) auto path
# ---------------------------------------------------------------------------
def test_schedule_overrides_topology_rounds():
    topo = Topology.two_level(2, 2, 20, root_rounds=9, group_rounds=9,
                              local_steps=9)
    r = Schedule(rounds=4, level_rounds=[3], local_steps=17).resolve(topo)
    assert r.rounds == 4
    assert r.chunk_tree.rounds == 1
    assert {c.rounds for c in r.chunk_tree.children} == {3}
    assert {l.rounds for l in r.chunk_tree.leaves()} == {17}
    # default: keep what the topology carries
    r2 = Schedule().resolve(topo)
    assert r2.rounds == 9
    assert {c.rounds for c in r2.chunk_tree.children} == {9}


def test_auto_rounds_reproduces_plan_hierarchical_h():
    """Satellite: Schedule(rounds='auto') == plan_hierarchical_h per level,
    wired end-to-end into Session.compile."""
    t_lp, t_cp, budget = 1e-5, 2e-5, 2.0
    topo = Topology.two_level(2, 2, 32, root_delay=0.05, group_delay=1e-4,
                              t_lp=t_lp)
    dm = DelayModel(t_total=budget, C=0.5, t_cp=t_cp, h_max=10**4)
    sched = Schedule(rounds="auto", delay=dm)
    X, y = gaussian_regression(m=topo.m_total, d=8)
    sess = Session.compile(Problem(X, y, lam=LAM), topo, sched)

    lp = plan_hierarchical_h(
        [FixedLevel("depth1", 2, 1e-4), FixedLevel("depth0", 2, 0.05)],
        C=0.5, delta=1.0 / 32, t_total=budget, t_lp=t_lp, t_cp=t_cp,
        h_max=10**4)
    assert [row["H"] for row in sess.level_plan] == [row["H"] for row in lp]
    leaves = sess.resolved.chunk_tree.leaves()
    assert {l.rounds for l in leaves} == {int(lp[0]["H"])}
    assert {c.rounds for c in sess.resolved.chunk_tree.children} == \
        {int(lp[1]["H"])}
    assert sess.default_rounds == max(1, int(budget / lp[-1]["round_time"]))

    res = sess.run(record_history=True)
    assert np.isfinite(res.gaps).all()


def test_auto_rounds_inherits_topology_t_cp():
    """DelayModel.t_cp=None (default) takes the aggregation cost from the
    topology instead of silently assuming 0."""
    t_lp, t_cp = 4e-5, 3e-3
    topo = Topology.star(3, 100, t_lp=t_lp, t_cp=t_cp, t_delay=0.1)
    r = Schedule.auto(t_total=1.0, h_max=10**5).resolve(topo)
    h_with = optimal_h(C=0.5, K=3, delta=1 / 100, t_total=1.0, t_lp=t_lp,
                       t_delay=0.1, t_cp=t_cp, h_max=10**5)[0]
    assert r.chunk_tree.leaves()[0].rounds == h_with
    # explicit t_cp still wins over the topology's
    r0 = Schedule.auto(t_total=1.0, t_cp=0.0, h_max=10**5).resolve(topo)
    h0 = optimal_h(C=0.5, K=3, delta=1 / 100, t_total=1.0, t_lp=t_lp,
                   t_delay=0.1, t_cp=0.0, h_max=10**5)[0]
    assert r0.chunk_tree.leaves()[0].rounds == h0


def test_optimal_h_monotone_in_delay_fig4b():
    """Satellite sanity check: larger link delay => H* non-decreasing (the
    paper's Fig. 4(b) trend), on a non-paper parameter set."""
    base = dict(C=0.6, K=4, delta=1 / 64, t_total=0.5, t_lp=2e-5, t_cp=1e-5,
                h_max=10**6)
    hs = [optimal_h(t_delay=r * base["t_lp"], **base)[0]
          for r in (0.0, 10.0, 1e3, 1e5, 1e7)]
    assert all(b >= a for a, b in zip(hs, hs[1:], strict=False)), hs
    assert hs[-1] > hs[0]


def test_auto_rounds_beats_fixed_default_time_to_gap():
    """Acceptance regression: on a slow-rooted two-level topology the
    eq.-(12) auto schedule reaches a strictly smaller duality gap than the
    topology's fixed default within the same simulated-time budget."""
    t_lp = 1e-5
    budget = 8.0
    topo = Topology.two_level(
        2, 2, 32, root_rounds=10, group_rounds=2, local_steps=16,
        t_lp=t_lp, root_delay=1e5 * t_lp, group_delay=1e-4)
    X, y = gaussian_regression(m=topo.m_total, d=16)
    prob = Problem(X, y, lam=0.05)

    fixed = Schedule().resolve(topo)
    t_fixed_rounds = max(1, int(budget / fixed.per_round_time))
    res_fixed = Session.compile(prob, topo).run(
        rounds=t_fixed_rounds, key=jax.random.PRNGKey(0))

    sched = Schedule.auto(t_total=budget, t_cp=0.0, h_max=2**12)
    sess = Session.compile(prob, topo, sched)
    res_auto = sess.run(key=jax.random.PRNGKey(0))

    # equal simulated budget on both sides
    assert res_auto.times[-1] <= budget and res_fixed.times[-1] <= budget
    assert res_auto.gaps[-1] < res_fixed.gaps[-1], (
        res_auto.gaps[-1], res_fixed.gaps[-1])


def test_auto_requires_delay_model_and_positive_tlp():
    topo = Topology.two_level(2, 2, 8)     # t_lp defaults to 0
    with pytest.raises(ValueError, match="DelayModel"):
        Schedule(rounds="auto").resolve(topo)
    with pytest.raises(ValueError, match="t_lp"):
        Schedule.auto(t_total=1.0).resolve(topo)


# ---------------------------------------------------------------------------
# Problem / loss registry
# ---------------------------------------------------------------------------
def test_problem_resolves_losses_by_name():
    X, y = gaussian_regression(m=12, d=3)
    assert Problem(X, y, loss="squared").loss is D.squared
    assert Problem(X, y, loss="logistic").loss is D.logistic
    p = Problem(X, y, loss="smooth_hinge_0.25")
    assert p.loss.gamma == 0.25
    assert D.get_loss("smooth_hinge_0.25") is p.loss     # registered
    assert Problem.svm(X, y, smoothing=0).loss is D.hinge
    with pytest.raises(KeyError):
        Problem(X, y, loss="no_such_loss")
    with pytest.raises(ValueError):
        Problem(X, y[:5])


def test_solve_one_shot_matches_session():
    topo = TOPOLOGIES["star"]()
    X, y = gaussian_regression(m=topo.m_total, d=6)
    prob = Problem(X, y, lam=LAM)
    key = jax.random.PRNGKey(1)
    a = solve(prob, topo, key=key, record_history=False)
    b = Session.compile(prob, topo).run(key=key, record_history=False)
    np.testing.assert_array_equal(np.asarray(a.alpha), np.asarray(b.alpha))


def test_session_validates_data_topology_mismatch():
    X, y = gaussian_regression(m=64, d=4)
    topo = Topology.star(4, 8)              # 32 != 64
    with pytest.raises(ValueError, match="assigns"):
        Session.compile(Problem(X, y), topo)


def test_objective_does_not_retrace_per_lambda():
    """Satellite regression: lam used to be a STATIC jit argument of the
    session's objective, retracing once per lambda in sweep workloads; as
    a traced scalar, two lambdas must share one compiled objective."""
    from repro.api.session import _objective
    topo = Topology.star(2, 16, rounds=2, local_steps=8)
    X, y = gaussian_regression(m=32, d=4)
    sess1 = Session.compile(Problem(X, y, lam=0.05), topo)
    sess1.run(record_history=True)
    before = _objective._cache_size()
    sess2 = Session.compile(Problem(X, y, lam=0.2), topo)
    res = sess2.run(record_history=True)
    assert _objective._cache_size() == before, "objective retraced on lam"
    # and the recorded objectives actually depend on the traced lam
    assert np.isfinite(res.gaps).all()
    direct = D.duality_gap(res.alpha, X, y, D.squared, 0.2)
    assert res.gaps[-1] == pytest.approx(float(direct), rel=1e-4)
