"""Fault-tolerance: checkpoint atomicity/retention/resume, elastic remesh,
straggler policies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.checkpoint import CheckpointManager, resume_or_init
from repro.runtime.elastic import (fold_batch, remesh_state,
                                   shrink_survivors, to_host)
from repro.runtime.straggler import AdaptiveSchedule, BoundedSkip, StepTimer


def _state(v=0.0):
    return {"params": {"w": jnp.full((8, 8), v), "b": jnp.zeros((8,))},
            "step": jnp.int32(int(v)),
            "bf16": jnp.full((4,), v, jnp.bfloat16)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    s = _state(3.0)
    mgr.save(3, s, metadata={"loss": 1.23})
    step, restored = mgr.restore(_state())
    assert step == 3
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(s),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert restored["bf16"].dtype == jnp.bfloat16


def test_checkpoint_retention_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(float(s)))
    assert mgr.all_steps() == [3, 4]
    step, r = mgr.restore(_state())
    assert step == 4 and float(r["params"]["w"][0, 0]) == 4.0


def test_checkpoint_ignores_partial_writes(tmp_path):
    """A crash mid-save (orphan .npz without sidecar) is never resumed."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _state(1.0))
    # simulate a crash: full npz written but no .json sidecar
    broken = tmp_path / "step_0000000009.npz"
    broken.write_bytes(b"not a checkpoint")
    assert mgr.latest_step() == 1
    step, _ = mgr.restore(_state())
    assert step == 1


def test_checkpoint_mixed_dtype_nested_roundtrip(tmp_path):
    """Exact dtype + structure preservation through the npz flatten:
    nested dict/list/tuple with bf16 (no numpy dtype: viewed as uint16),
    f32, f64, int32 and uint32 leaves."""
    s = {"k": jnp.arange(2, dtype=jnp.uint32),
         "nest": {"a": [jnp.full((3,), 1.5, jnp.bfloat16),
                        jnp.full((2, 2), -2.0, jnp.float32)],
                  "b": (jnp.int32(7), np.float64(0.25))},
         "c": np.arange(4, dtype=np.float64)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, s)
    step, r = mgr.restore(jax.tree.map(np.zeros_like, s))
    assert step == 5
    for a, b in zip(jax.tree.leaves(r), jax.tree.leaves(s), strict=True):
        assert a.dtype == b.dtype, (a.dtype, b.dtype)
        np.testing.assert_array_equal(np.asarray(a, np.float64),
                                      np.asarray(b, np.float64))


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    mgr.save(1, _state(1.0))
    mgr.save(2, _state(2.0))
    mgr.wait()
    assert mgr.latest_step() == 2


def test_checkpoint_async_save_failure_surfaces(tmp_path, monkeypatch):
    """A failed background write must NOT die silently on the save thread:
    wait() (or the next save) re-raises it, naming the failed step."""
    mgr = CheckpointManager(str(tmp_path), async_save=True)

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(np, "savez", boom)
    mgr.save(1, _state(1.0))
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        mgr.wait()
    assert mgr.latest_step() is None          # nothing published
    monkeypatch.undo()
    mgr.save(2, _state(2.0))                  # the manager recovers
    mgr.wait()
    assert mgr.latest_step() == 2


def test_checkpoint_keep_one_always_restorable(tmp_path):
    """keep=1 retention: after every save the newest complete checkpoint
    is restorable (GC never deletes the step it just published), and
    retired steps are fully gone -- payload AND sidecar."""
    mgr = CheckpointManager(str(tmp_path), keep=1)
    for s in (1, 2, 3, 4, 5):
        mgr.save(s, _state(float(s)))
        assert mgr.all_steps() == [s]
        step, r = mgr.restore(_state())
        assert step == s and float(r["params"]["w"][0, 0]) == float(s)
    assert len(list(tmp_path.glob("step_*"))) == 2   # one npz + one json
    with pytest.raises(FileNotFoundError):
        mgr.restore(_state(), step=1)                # retired explicitly
    with pytest.raises(ValueError):
        CheckpointManager(str(tmp_path), keep=0)


def test_resume_or_init(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    step, s = resume_or_init(mgr, lambda: _state(0.0))
    assert step == 0
    mgr.save(7, _state(7.0))
    step, s = resume_or_init(mgr, lambda: _state(0.0))
    assert step == 7 and float(s["params"]["w"][0, 0]) == 7.0


def test_elastic_remesh_preserves_values():
    """Host -> mesh A -> host -> mesh B roundtrip is value-identical."""
    from repro.launch.mesh import make_host_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_host_mesh()
    s = _state(5.0)
    sh = jax.tree.map(lambda t: NamedSharding(mesh, P()), s)
    placed = remesh_state(to_host(s), sh)
    back = to_host(placed)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(s),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_fold_batch_invariance():
    from repro.launch.mesh import make_abstract_mesh
    m1 = make_abstract_mesh((16, 16), ("data", "model"))
    m2 = make_abstract_mesh((8, 16), ("data", "model"))
    assert fold_batch(256, m1)["per_replica"] * 16 == 256
    assert fold_batch(256, m2)["per_replica"] * 8 == 256
    with pytest.raises(AssertionError):
        fold_batch(100, m1)  # 100 % 16 != 0


def test_shrink_survivors_respects_tp_group():
    assert shrink_survivors(512, lost=3, model_parallel=16) == 496
    assert shrink_survivors(512, lost=16, model_parallel=16) == 496
    assert shrink_survivors(256, lost=1, model_parallel=16) == 240


def test_step_timer_straggler_detection():
    t = StepTimer()
    for _ in range(20):
        t.observe(1.0 + np.random.default_rng(0).normal() * 0.0)
    assert not t.is_straggling(1.01)
    assert t.is_straggling(10.0)


def test_adaptive_schedule_monotone_in_delay():
    """Paper Fig. 4(b): larger delay => larger (or equal) optimal H."""
    s = AdaptiveSchedule(C=0.5, delta=1 / 300, t_total=1.0, K=3,
                         h_max=10**6, hysteresis=1.0)
    hs = [s.replan(t_lp=4e-5, t_delay=4e-5 * r, t_cp=3e-5)
          for r in (0, 10, 1e3, 1e5)]
    assert all(b >= a for a, b in zip(hs, hs[1:], strict=False)), hs
    assert hs[-1] > hs[0]


def test_bounded_skip_forces_barrier():
    p = BoundedSkip(max_consecutive=2)
    assert p.decide(True) is True
    assert p.decide(True) is True
    assert p.decide(True) is False   # forced sync after 2 skips
    assert p.decide(False) is False
