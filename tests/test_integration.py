"""End-to-end integration: checkpoint/restart continuity, elastic remesh
mid-training, hierarchical H planning, and the serve path."""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.delay import SyncLevel, ICI_LINK, DCI_LINK, \
    plan_hierarchical_h
from repro.data.lm import lm_batch
from repro.launch.train import train

CFG = ModelConfig(
    name="it-tiny", family="dense", num_layers=2, d_model=32, num_heads=4,
    num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64, q_chunk_size=16,
    logits_chunk=16, remat=False,
)


def test_train_restart_continues_stream(tmp_path):
    """Train 6 steps with checkpoints every 2; 'crash'; resume and train to
    10. The resumed run must (a) start from the checkpointed step and (b)
    end with finite, decreasing-ish loss. Data is stateless-deterministic,
    so the resumed stream continues exactly where the crash happened."""
    ck = str(tmp_path / "ck")
    out1 = train(CFG, steps=6, batch=4, seq=32, mode="sync",
                 ckpt_dir=ck, ckpt_every=2, log_every=100, lr=1e-3)
    assert len(out1["history"]) == 6
    # resume: train() reads the newest checkpoint (step 6) automatically
    out2 = train(CFG, steps=10, batch=4, seq=32, mode="sync",
                 ckpt_dir=ck, ckpt_every=2, log_every=100, lr=1e-3)
    steps2 = [h["step"] for h in out2["history"]]
    assert steps2 == [7, 8, 9, 10], steps2
    assert np.isfinite(out2["final_loss"])


def test_train_restart_matches_uninterrupted(tmp_path):
    """Interrupted-and-resumed == uninterrupted, step for step (same
    deterministic data, same optimizer state through the checkpoint)."""
    ck = str(tmp_path / "ck2")
    train(CFG, steps=3, batch=4, seq=32, mode="sync",
          ckpt_dir=ck, ckpt_every=3, log_every=100, lr=1e-3)
    out_resumed = train(CFG, steps=5, batch=4, seq=32, mode="sync",
                        ckpt_dir=ck, ckpt_every=100, log_every=100,
                        lr=1e-3)
    out_straight = train(CFG, steps=5, batch=4, seq=32, mode="sync",
                         ckpt_dir=None, log_every=100, lr=1e-3)
    # compare the final losses (same seed, same stream)
    np.testing.assert_allclose(
        out_resumed["final_loss"], out_straight["final_loss"],
        rtol=2e-3)


def test_treesync_training_runs(tmp_path):
    out = train(CFG, steps=4, batch=8, seq=32, mode="treesync",
                periods=[2], log_every=100, lr=1e-3)
    assert len(out["history"]) == 4
    assert np.isfinite(out["final_loss"])


def test_elastic_shrink_grow_roundtrip():
    """Simulate losing half the mesh: state re-shards onto the smaller
    mesh, trains a step, grows back -- values preserved through hops."""
    from repro.launch import sharding as sh
    from repro.launch.steps import make_train_step
    from repro.models.transformer import init_params
    from repro.optim import get_optimizer
    from repro.runtime.elastic import remesh_state, to_host

    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >=2 devices")
    big = jax.make_mesh((n,), ("data",))
    small = jax.make_mesh((n // 2,), ("data",))

    params = init_params(CFG, jax.random.PRNGKey(0))
    pshape = jax.eval_shape(lambda: params)
    sh_big = sh.param_shardings(CFG, pshape, big)
    sh_small = sh.param_shardings(CFG, pshape, small)

    placed = remesh_state(params, sh_big)
    moved = remesh_state(to_host(placed), sh_small)  # shrink
    # one step on the shrunken mesh
    opt = get_optimizer(CFG, lr=1e-3)
    opt_state = opt.init(moved)
    step = jax.jit(make_train_step(CFG, opt))
    batch = lm_batch(CFG, n, 32, step=0)
    p2, _, m = step(moved, opt_state, batch)
    assert np.isfinite(float(m["loss"]))
    # grow back
    back = remesh_state(to_host(p2), sh_big)
    for a, b in zip(jax.tree.leaves(to_host(back)), jax.tree.leaves(p2),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plan_hierarchical_h_slow_links_get_larger_periods():
    """delay.py's recursive eq.-(12) planner: the slow DCI level gets a
    period >= the fast ICI level's."""
    levels = [
        SyncLevel("intra_pod", group_size=16, link=ICI_LINK,
                  msg_bytes=256e6),
        SyncLevel("cross_pod", group_size=2, link=DCI_LINK,
                  msg_bytes=256e6),
    ]
    plan = plan_hierarchical_h(levels, C=0.5, delta=1e-3, t_total=3600.0,
                               t_lp=0.05, h_max=1000)
    assert plan[0]["name"] == "intra_pod" and plan[1]["name"] == "cross_pod"
    assert plan[0]["H"] >= 1 and plan[1]["H"] >= 1
    # the cross-pod round is strictly more expensive per sync; its round
    # time must amortize more local work
    assert plan[1]["round_time"] > plan[0]["round_time"]


def test_serve_generate_roundtrip():
    from repro.launch.serve import generate
    from repro.models.transformer import init_params
    params = init_params(CFG, jax.random.PRNGKey(0))
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                            0, CFG.vocab_size)}
    out, stats = generate(CFG, params, prompts, gen_tokens=6)
    assert out.shape == (2, 6)
    assert stats["tok_per_s"] > 0
