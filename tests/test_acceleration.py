"""Accelerated server momentum (``Schedule(acceleration=)`` /
``get_method("sdca_acc")``; Ma et al., arXiv 1711.05305).

The load-bearing claims:

  * ``acceleration=0`` is BIT-identical to the plain ``"sdca"`` method on
    every backend -- the momentum extrapolation is selected (not scaled)
    out of the combine, so the zero coefficient leaves no float residue;
  * the coefficient is a RUNTIME scalar operand: ``run(acceleration=)``
    overrides the compiled value with zero retraces and matches a session
    compiled at that value bit for bit;
  * ``acceleration>0`` buys convergence: fewer rounds to a given duality
    gap on the paper's star topology;
  * the eq.-(12) planner picks up the accelerated per-round factor
    g = 1 - s^(1 - a/2) (``acceleration=0`` recovers eq. (11) exactly);
  * composition limits are validated loudly (plain sessions reject the
    run-time override; straggler/checkpoint don't compose).
"""
import jax
import numpy as np
import pytest

from repro.api import Problem, Schedule, Session, Topology
from repro.core import delay
from repro.core.engine.method import get_method
from repro.data.synthetic import gaussian_regression

LAM = 0.1


def _star(backend):
    if backend == "mesh":
        n = len(jax.devices())
        return Topology.star(n, 96 // n, rounds=5, local_steps=16)
    return Topology.star(4, 24, rounds=5, local_steps=16)


def _problem(topo):
    X, y = gaussian_regression(m=topo.m_total, d=8)
    return Problem(X, y, loss="squared", lam=LAM)


# ---------------------------------------------------------------------------
# method registry + planner semantics
# ---------------------------------------------------------------------------
def test_sdca_acc_is_a_registered_method():
    m = get_method("sdca_acc")
    assert m.name == "sdca_acc"
    assert get_method("sdca").name == "sdca"


def test_per_round_factor_accelerated_semantics():
    """g = 1 - s^(1 - a/2): a=0 recovers eq. (11) exactly, a>0 shrinks g
    (faster contraction), a=1 is the square-root rate."""
    H, C, K, delta = 32, 0.5, 4, 0.05
    g0 = delay.per_round_factor(H, C, K, delta)
    assert delay.per_round_factor(H, C, K, delta, acceleration=0.0) == g0
    s = 1.0 - g0
    assert delay.per_round_factor(H, C, K, delta, acceleration=1.0) == \
        pytest.approx(1.0 - s ** 0.5)
    gs = [delay.per_round_factor(H, C, K, delta, acceleration=a)
          for a in (0.0, 0.3, 0.6, 1.0)]
    assert all(b < a for a, b in zip(gs, gs[1:], strict=False)), gs
    for bad in (-0.1, 1.5):
        with pytest.raises(ValueError, match="acceleration"):
            delay.per_round_factor(H, C, K, delta, acceleration=bad)


def test_optimal_h_accelerated_bound_no_worse():
    """Momentum can only improve the planned eq.-(12) log-bound."""
    kw = dict(C=0.5, K=4, delta=0.05, t_total=50.0, t_lp=0.01,
              t_delay=0.5, t_cp=0.0, h_max=10**5)
    _, v_plain = delay.optimal_h(**kw)
    _, v_acc = delay.optimal_h(acceleration=0.8, **kw)
    assert v_acc <= v_plain


def test_schedule_acceleration_validation():
    with pytest.raises(ValueError, match="acceleration"):
        Schedule(acceleration=1.5)
    with pytest.raises(ValueError, match="acceleration"):
        Schedule(acceleration=-0.2)
    assert Schedule(acceleration=0.0).acceleration == 0.0
    assert Schedule().acceleration is None


# ---------------------------------------------------------------------------
# acceleration=0 bit-identity, runtime override, convergence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["vmap", "pallas", "mesh"])
def test_acceleration_zero_bit_identical_to_plain(backend):
    """The zero coefficient selects the plain combine out of the program
    (jnp.where, not a multiply), so sdca_acc(0) == sdca bitwise --
    iterates, history, and RNG chain -- on every backend."""
    topo = _star(backend)
    prob = _problem(topo)
    key = jax.random.PRNGKey(0)
    plain = Session.compile(prob, topo, backend=backend).run(key=key)
    acc0 = Session.compile(prob, topo, Schedule(acceleration=0.0),
                           backend=backend).run(key=key)
    np.testing.assert_array_equal(np.asarray(acc0.alpha),
                                  np.asarray(plain.alpha))
    np.testing.assert_array_equal(np.asarray(acc0.w), np.asarray(plain.w))
    np.testing.assert_array_equal(np.asarray(acc0.next_key),
                                  np.asarray(plain.next_key))
    assert [h["gap"] for h in acc0.history] == \
        [h["gap"] for h in plain.history]


def test_acceleration_is_a_runtime_operand():
    """run(acceleration=) swaps the coefficient without recompiling and
    matches a session compiled at that value bit for bit."""
    topo = _star("vmap")
    prob = _problem(topo)
    key = jax.random.PRNGKey(3)
    sess = Session.compile(prob, topo, Schedule(acceleration=0.7))
    override = sess.run(key=key, acceleration=0.3)
    compiled = Session.compile(prob, topo, Schedule(acceleration=0.3)).run(
        key=key)
    np.testing.assert_array_equal(np.asarray(override.alpha),
                                  np.asarray(compiled.alpha))
    np.testing.assert_array_equal(np.asarray(override.w),
                                  np.asarray(compiled.w))
    # the coefficient is NOT an executor cache axis: both values run the
    # same compiled program
    with pytest.raises(ValueError, match="acceleration"):
        sess.run(key=key, acceleration=2.0)


def test_acceleration_speeds_convergence():
    """The point of the flavor: at equal rounds the momentum run reaches a
    strictly smaller duality gap on the paper's star topology."""
    topo = Topology.star(8, 32, rounds=40, local_steps=8)
    X, y = gaussian_regression(m=256, d=24)
    prob = Problem(X, y, loss="squared", lam=LAM)
    key = jax.random.PRNGKey(0)
    plain = Session.compile(prob, topo).run(key=key)
    acc = Session.compile(prob, topo, Schedule(acceleration=0.6)).run(
        key=key)
    assert acc.history[-1]["gap"] < 0.5 * plain.history[-1]["gap"]


def test_acceleration_composition_validations(tmp_path):
    """Loud failures instead of silently-wrong runs: the override needs an
    accelerated session, and straggler/checkpoint don't compose."""
    from repro.runtime.fault import CheckpointPolicy
    from repro.runtime.straggler import StragglerPolicy
    topo = _star("vmap")
    prob = _problem(topo)
    plain = Session.compile(prob, topo)
    with pytest.raises(ValueError, match="Schedule\\(acceleration"):
        plain.run(acceleration=0.5)
    sess = Session.compile(prob, topo, Schedule(acceleration=0.5))
    with pytest.raises(ValueError, match="straggler"):
        sess.run(straggler=StragglerPolicy(max_consecutive=1, seed=0))
    with pytest.raises(ValueError, match="checkpoint"):
        sess.run(checkpoint=CheckpointPolicy(directory=tmp_path, every=1))
