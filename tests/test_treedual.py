"""TreeDualMethod (Algorithms 1-3) system tests."""
import numpy as np
import pytest

from repro.core import dual as D
from repro.core.tree import star, two_level
from repro.core.treedual import cocoa_star_solve, tree_dual_solve
from repro.data.synthetic import gaussian_regression, wine_like

LAM = 0.1


def test_cocoa_star_converges():
    X, y = gaussian_regression(m=240, d=30)
    res = cocoa_star_solve(
        X, y, n_workers=4, loss=D.squared, lam=LAM,
        outer_rounds=30, local_steps=6 * 60,  # H = m_k epochs-ish
    )
    gap0 = res.history[0]["gap"]
    assert res.history[-1]["gap"] < 1e-2 * gap0
    # w returned must equal A alpha
    np.testing.assert_allclose(
        np.asarray(res.w), np.asarray(D.w_of_alpha(res.alpha, X, LAM)),
        rtol=1e-4, atol=1e-5,
    )


def test_dual_monotone_over_rounds():
    X, y = gaussian_regression(m=160, d=20)
    res = cocoa_star_solve(
        X, y, n_workers=4, loss=D.squared, lam=LAM,
        outer_rounds=15, local_steps=80,
    )
    duals = res.duals
    assert (np.diff(duals) >= -1e-6).all()


def test_two_level_tree_converges_same_optimum_as_star():
    X, y = wine_like(m=240)
    lam = 0.3
    res_star = cocoa_star_solve(
        X, y, n_workers=4, loss=D.squared, lam=lam,
        outer_rounds=40, local_steps=240,
    )
    tree = two_level(
        n_groups=2, workers_per_group=2, m_per_worker=60,
        root_rounds=20, group_rounds=3, local_steps=240,
    )
    res_tree = tree_dual_solve(tree, X, y, loss=D.squared, lam=lam)
    a_star = D.ridge_dual_optimum(X, y, lam)
    d_star = float(D.dual_value(a_star, X, y, D.squared, lam))
    assert d_star - res_star.duals[-1] < 5e-3 * abs(d_star) + 5e-3
    assert d_star - res_tree.duals[-1] < 5e-3 * abs(d_star) + 5e-3


def test_tree_with_group_rounds_one_matches_star_updates():
    """A 2-level tree with T_group=1 performs star-like averaging; it must
    still be monotone and converge (the exact sequence differs because of the
    nested 1/K scalings, which the paper's analysis accounts for)."""
    X, y = gaussian_regression(m=120, d=10)
    tree = two_level(
        n_groups=2, workers_per_group=2, m_per_worker=30,
        root_rounds=25, group_rounds=1, local_steps=120,
    )
    res = tree_dual_solve(tree, X, y, loss=D.squared, lam=LAM)
    assert (np.diff(res.duals) >= -1e-6).all()
    assert res.history[-1]["gap"] < 0.05 * res.history[0]["gap"]


def test_three_level_tree_runs():
    """Depth-3 recursion (the paper's algorithm is defined for any depth)."""
    from repro.core.tree import TreeNode

    def leaf(name):
        return TreeNode(name=name, rounds=60, data_size=20, t_lp=1e-5)

    g0 = TreeNode(name="g0", children=(leaf("l0"), leaf("l1")), rounds=2)
    g1 = TreeNode(name="g1", children=(leaf("l2"), leaf("l3")), rounds=2)
    mid = TreeNode(name="mid", children=(g0, g1), rounds=2)
    g2 = TreeNode(name="g2", children=(leaf("l4"), leaf("l5")), rounds=2)
    root = TreeNode(name="root", children=(mid, g2), rounds=12)

    X, y = gaussian_regression(m=root.total_data(), d=8)
    res = tree_dual_solve(root, X, y, loss=D.squared, lam=LAM)
    assert res.history[-1]["gap"] < 0.1 * res.history[0]["gap"]
    assert (np.diff(res.duals) >= -1e-6).all()


def test_simulated_time_star_matches_eq9():
    """Star round time must equal eq. (9): (t_lp H + t_delay + t_cp) * T."""
    t_lp, t_cp, t_delay, H, T = 4e-5, 3e-5, 0.4, 100, 7
    tree = star(3, 10, outer_rounds=T, local_steps=H,
                t_lp=t_lp, t_cp=t_cp, t_delay=t_delay)
    expected = (t_lp * H + t_delay + t_cp) * T
    assert tree.solve_time() == pytest.approx(expected, rel=1e-9)


def test_simulated_time_two_level():
    tree = two_level(
        n_groups=2, workers_per_group=2, m_per_worker=10,
        root_rounds=3, group_rounds=5, local_steps=10,
        t_lp=1e-4, t_cp=1e-5, root_delay=1.0, group_delay=0.01,
    )
    # group round: H*t_lp + group->? the group's own solve: 5*(10*1e-4+0.01+1e-5)
    group_solve = 5 * (10 * 1e-4 + 0.01 + 1e-5)
    expected = 3 * (group_solve + 1.0 + 1e-5)
    assert tree.solve_time() == pytest.approx(expected, rel=1e-9)
