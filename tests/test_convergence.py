"""Theorem 1/2 + Proposition 1 rate validation: the empirical gap must decay
at least as fast as the theoretical bound (in expectation over seeds)."""
import jax
import numpy as np

from repro.core import convergence as conv
from repro.core import dual as D
from repro.core.tree import star, two_level
from repro.core.treedual import tree_dual_solve
from repro.data.synthetic import gaussian_regression


def test_rho_min_power_matches_eigh():
    X, _ = gaussian_regression(m=60, d=15)
    lam = 0.1
    A = np.asarray(D.data_matrix(X, lam))
    blocks = [slice(0, 20), slice(20, 40), slice(40, 60)]
    exact = conv.rho_min(A, blocks, lam, 60)
    approx = conv.rho_min_power(A, blocks, lam, 60, iters=500)
    assert abs(exact - approx) <= 0.02 * exact + 1e-8


def test_rho_min_zero_for_single_block():
    X, _ = gaussian_regression(m=40, d=10)
    lam = 0.1
    A = np.asarray(D.data_matrix(X, lam))
    assert conv.rho_min(A, [slice(0, 40)], lam, 40) < 1e-8


def test_leaf_theta_formula():
    # Prop 1: H=0 -> Theta=1 (no progress); H->inf -> 0
    assert conv.leaf_theta(0.1, 100, 1.0, 25, 0) == 1.0
    assert conv.leaf_theta(0.1, 100, 1.0, 25, 10**6) < 1e-9
    th1 = conv.leaf_theta(0.1, 100, 1.0, 25, 50)
    th2 = conv.leaf_theta(0.1, 100, 1.0, 25, 100)
    assert 0 < th2 < th1 < 1


def test_theorem2_bound_holds_star():
    """Empirical mean gap across seeds <= Theorem-2 bound (with slack for the
    finite seed count)."""
    m, d, K, lam = 120, 15, 4, 0.5
    X, y = gaussian_regression(m=m, d=d)
    A = np.asarray(D.data_matrix(X, lam))
    blocks = [slice(k * m // K, (k + 1) * m // K) for k in range(K)]
    rho = conv.rho_min(A, blocks, lam, m)
    H, T = 300, 8
    theta_leaf = conv.leaf_theta(lam, m, D.squared.gamma, m // K, H)
    theta_round = 1.0 - (1.0 - theta_leaf) / K * (
        lam * m * D.squared.gamma / (rho + lam * m * D.squared.gamma)
    )

    a_star = D.ridge_dual_optimum(X, y, lam)
    d_star = float(D.dual_value(a_star, X, y, D.squared, lam))

    tree = star(K, m // K, outer_rounds=T, local_steps=H)
    gaps = []
    for seed in range(5):
        res = tree_dual_solve(tree, X, y, loss=D.squared, lam=lam,
                              key=jax.random.PRNGKey(seed))
        gaps.append(d_star - np.array(res.duals))
    mean_gap = np.mean(gaps, axis=0)  # over seeds, per round
    bound = mean_gap[0] * theta_round ** np.arange(T + 1)
    # allow 2x slack: the bound is in expectation, 5 seeds only
    assert (mean_gap <= 2.0 * bound + 1e-7).all()


def test_tree_theta_recursion_monotone_in_rounds():
    X, _ = gaussian_regression(m=80, d=10)
    lam = 0.2
    A = np.asarray(D.data_matrix(X, lam))

    def make(root_rounds, group_rounds, H):
        return two_level(2, 2, 20, root_rounds=root_rounds,
                         group_rounds=group_rounds, local_steps=H)

    th_small = conv.tree_theta(make(1, 1, 50), A, lam, 1.0)
    th_more_local = conv.tree_theta(make(1, 1, 200), A, lam, 1.0)
    th_more_rounds = conv.tree_theta(make(3, 2, 50), A, lam, 1.0)
    assert 0 < th_more_local < th_small < 1
    assert 0 < th_more_rounds < th_small < 1


def test_tree_theta_bound_holds_two_level():
    m, lam = 80, 0.5
    X, y = gaussian_regression(m=m, d=10)
    A = np.asarray(D.data_matrix(X, lam))
    R = 6
    tree = two_level(2, 2, m // 4, root_rounds=R, group_rounds=2,
                     local_steps=200)
    theta_root = conv.tree_theta(tree, A, lam, D.squared.gamma)
    # per-root-round factor
    theta_round = theta_root ** (1.0 / R)

    a_star = D.ridge_dual_optimum(X, y, lam)
    d_star = float(D.dual_value(a_star, X, y, D.squared, lam))
    gaps = []
    for seed in range(5):
        res = tree_dual_solve(tree, X, y, loss=D.squared, lam=lam,
                              key=jax.random.PRNGKey(100 + seed))
        gaps.append(d_star - np.array(res.duals))
    mean_gap = np.mean(gaps, axis=0)
    bound = mean_gap[0] * theta_round ** np.arange(R + 1)
    assert (mean_gap <= 2.0 * bound + 1e-7).all()
