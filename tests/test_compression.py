"""Compressed per-edge tree sync: spec parsing/dataclasses, roundtrip
invariants, error feedback, plan-IR compression fields and byte
accounting, the exactness guarantee of ``compression="none"`` on every
backend, compressed convergence, the delay-aware auto-selection, and the
``mesh_sync="reduce_scatter"`` sharded-server path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Problem, Schedule, Session, Topology, solve
from repro.core import compression as comp
from repro.core.delay import FixedLevel, choose_compression
from repro.core.engine import mesh as mesh_mod
from repro.core.engine.plan import compile_tree, plan_bytes_per_round
from repro.core.tree import TreeNode, star
from repro.data.synthetic import gaussian_regression

LAM = 0.1


# ---------------------------------------------------------------------------
# compressor dataclasses and spec parsing
# ---------------------------------------------------------------------------
def test_compressors_are_plain_frozen_dataclasses():
    """Real dataclass fields (no __init__ workarounds): construction by
    field, frozen-ness, and derived name/ratio all behave."""
    c = comp.TopKCompressor(0.25)
    assert c.frac == 0.25
    assert {f.name for f in dataclasses.fields(c)} >= {"frac", "name",
                                                       "ratio"}
    assert c.name == "topk_0.25" and c.ratio == 0.5
    with pytest.raises(dataclasses.FrozenInstanceError):
        c.frac = 0.5
    with pytest.raises(ValueError):
        comp.TopKCompressor(0.0)
    assert comp.Int8Compressor().ratio == comp.INT8_RATIO
    assert comp.NoCompression().ratio == 1.0
    # the registry default and the spec path agree
    assert comp.COMPRESSORS["topk"]().frac == comp.DEFAULT_TOPK_FRAC
    assert comp.get_compressor("topk_0.05").frac == 0.05


def test_parse_spec_and_ratios():
    assert comp.parse_spec(None) == (comp.KIND_NONE, 0.0)
    assert comp.parse_spec("int8") == (comp.KIND_INT8, 0.0)
    assert comp.parse_spec("topk_0.1") == (comp.KIND_TOPK, 0.1)
    for bad in ("gzip", "topk_0", "topk_1.5"):
        with pytest.raises(ValueError):
            comp.parse_spec(bad)
    # int8: 1 byte/code + one f32 scale per 32-block, exactly
    assert comp.INT8_RATIO == 0.28125
    assert comp.wire_ratio(comp.KIND_INT8) == 0.28125
    # top-k ships (value, index) pairs, capped at the dense size
    assert comp.wire_ratio(comp.KIND_TOPK, 0.1) == 0.2
    assert comp.wire_ratio(comp.KIND_TOPK, 0.9) == 1.0


def test_topk_small_arrays_never_empty():
    """k clamps to >= 1 so tiny vectors still make progress (the k==0
    guard)."""
    assert comp.topk_count(10, 0.001) == 1
    assert comp.topk_count(10, 1.0) == 10
    assert comp.topk_count(0, 0.5) == 0
    x = jnp.asarray([0.1, -3.0, 0.2])
    vals, idx = comp.topk_sparsify(x, 0.01)
    assert vals.shape == (1,) and int(idx[0]) == 1
    # roundtrip with k below 1 behaves as k=1
    y = comp.topk_roundtrip(x, 0)
    np.testing.assert_array_equal(np.asarray(y), [0.0, -3.0, 0.0])


@pytest.mark.parametrize("n", [1, 5, 32, 33, 100])
def test_roundtrips_preserve_shape_and_dtype(n):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,), jnp.float32)
    for y in (comp.int8_roundtrip(x), comp.topk_roundtrip(x, max(n // 4, 1))):
        assert y.shape == x.shape and y.dtype == x.dtype
    # blockwise int8 error bound holds on non-multiple-of-BLOCK sizes too
    err = np.abs(np.asarray(comp.int8_roundtrip(x) - x)).max()
    assert err <= np.abs(np.asarray(x)).max() / 254.0 + 1e-7


def test_error_feedback_recovers_truncated_mass():
    """EF loop: with a constant per-round delta, the cumulative
    reconstruction tracks the cumulative truth -- the residual re-sends
    what compression dropped instead of losing it."""
    d = 64
    delta = jax.random.normal(jax.random.PRNGKey(0), (d,), jnp.float32)
    k = comp.topk_count(d, 0.1)
    res = jnp.zeros((d,), jnp.float32)
    got = jnp.zeros((d,), jnp.float32)
    for t in range(1, 41):
        target = delta + res
        approx = comp.topk_roundtrip(target, k)
        res = target - approx
        got = got + approx
        # invariant: sent-so-far + residual == truth-so-far, exactly
        np.testing.assert_allclose(np.asarray(got + res),
                                   np.asarray(t * delta.astype(jnp.float32)),
                                   rtol=1e-4, atol=1e-4)
    # and the carried residual stays bounded (no drift)
    assert float(jnp.abs(res).max()) <= float(jnp.abs(delta).max()) * d


# ---------------------------------------------------------------------------
# plan IR: per-(depth, leaf) compression fields and byte accounting
# ---------------------------------------------------------------------------
def test_plan_compression_fields_and_fingerprint():
    tree = star(4, 8, outer_rounds=1, local_steps=4)
    p0 = compile_tree(tree)
    p1 = compile_tree(tree, compression="int8")
    assert not p0.has_compression and p1.has_compression
    assert p1.compress_kind.shape == (1, 4)
    assert (p1.compress_kind == comp.KIND_INT8).all()
    assert p0.fingerprint != p1.fingerprint
    # "none" IS the uncompressed plan (same fingerprint -> same cached
    # executor -> bit-identity by construction)
    assert compile_tree(tree, compression="none").fingerprint == \
        p0.fingerprint


def test_plan_per_edge_override_beats_level_default():
    kids = tuple(
        TreeNode(name=f"W{k}", rounds=4, data_size=8,
                 up_compress="topk_0.2" if k == 0 else "")
        for k in range(3))
    tree = TreeNode(name="root", children=kids, rounds=1)
    p = compile_tree(tree, compression="int8")
    assert p.compress_kind[0, 0] == comp.KIND_TOPK
    assert p.compress_frac[0, 0] == np.float32(0.2)
    assert (p.compress_kind[0, 1:] == comp.KIND_INT8).all()


def test_plan_bytes_per_round_exact_ratio():
    tree = star(4, 8, outer_rounds=1, local_steps=4)
    d = 320
    b0 = plan_bytes_per_round(compile_tree(tree), d)
    b1 = plan_bytes_per_round(compile_tree(tree, compression="int8"), d)
    assert b0 == 4 * 4 * d          # 4 edges x one f32 d-vector per round
    assert b1 / b0 == comp.INT8_RATIO


# ---------------------------------------------------------------------------
# executors: "none" exactness, compressed convergence, EF across chunks
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_problem():
    topo = Topology.star(4, 32, rounds=30, local_steps=32, t_lp=1e-6,
                         t_delay=1e-3)
    X, y = gaussian_regression(m=topo.m_total, d=24)
    return Problem.ridge(X, y, lam=LAM), topo


@pytest.mark.parametrize("backend", ["vmap", "pallas", "mesh"])
def test_none_is_bit_identical_on_every_backend(backend, small_problem):
    prob, _ = small_problem
    n = len(jax.devices()) if backend == "mesh" else 4
    topo = Topology.star(n, 128 // n, rounds=10, local_steps=32)
    X, y = gaussian_regression(m=topo.m_total, d=24)
    prob = Problem.ridge(X, y, lam=LAM)
    key = jax.random.PRNGKey(3)
    r0 = solve(prob, topo, Schedule(), backend=backend, key=key)
    r1 = solve(prob, topo, Schedule(compression="none"), backend=backend,
               key=key)
    np.testing.assert_array_equal(np.asarray(r0.alpha), np.asarray(r1.alpha))
    np.testing.assert_array_equal(np.asarray(r0.w), np.asarray(r1.w))


@pytest.mark.parametrize("spec", ["int8", "topk_0.25"])
def test_compressed_run_reaches_same_gap(spec, small_problem):
    """EF-compressed syncs converge to the same duality gap as the exact
    run -- while shipping >= 2x fewer simulated bytes per round."""
    prob, topo = small_problem
    key = jax.random.PRNGKey(0)
    s_ex = Session.compile(prob, topo)
    s_c = Session.compile(prob, topo, Schedule(compression=spec))
    assert s_c.plan.has_compression
    g_ex = s_ex.run(key=key).history[-1]["gap"]
    g_c = s_c.run(key=key).history[-1]["gap"]
    target = 1e-3
    assert g_ex < target and g_c < target, (g_ex, g_c)
    assert s_ex.bytes_per_round / s_c.bytes_per_round >= 2.0
    # and the simulated clock reflects the cheaper wire
    assert s_c.resolved.per_round_time < s_ex.resolved.per_round_time


def test_compressed_host_split_runs_match_state_carry(small_problem):
    """Chunked execution threads the EF residuals across root rounds
    (carry_state executors): 30 chunked rounds == the same 30 rounds run
    in one session call, and histories are reproducible."""
    prob, topo = small_problem
    key = jax.random.PRNGKey(5)
    sess = Session.compile(prob, topo, Schedule(compression="int8"))
    r1 = sess.run(rounds=30, key=key, record_history=False)
    r2 = sess.run(rounds=30, key=key, record_history=False)
    np.testing.assert_array_equal(np.asarray(r1.alpha), np.asarray(r2.alpha))
    np.testing.assert_array_equal(np.asarray(r1.w), np.asarray(r2.w))


def test_compressed_sweep_members_match_standalone(small_problem):
    """Compressed plans opt out of the fused vmapped dispatch (EF state
    isn't modeled there) but sweep members still reproduce standalone
    runs exactly."""
    prob, topo = small_problem
    sess = Session.compile(prob, topo, Schedule(compression="int8"))
    lams = [0.2, 0.05]
    rs = sess.sweep(lams=lams, rounds=8, record_history=False)
    for lam, a in zip(lams, rs.alphas, strict=True):
        ref = sess.run(rounds=8, key=jax.random.PRNGKey(0), lam=lam,
                       record_history=False)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(ref.alpha))


# ---------------------------------------------------------------------------
# mesh backend: reduce_scatter sync + compression
# ---------------------------------------------------------------------------
def test_mesh_reduce_scatter_matches_psum():
    n = len(jax.devices())
    topo = Topology.star(n, 128 // n, rounds=8, local_steps=32)
    X, y = gaussian_regression(m=topo.m_total, d=37)   # odd d: padded shards
    prob = Problem.ridge(X, y, lam=LAM)
    key = jax.random.PRNGKey(1)
    r_ps = solve(prob, topo, backend="mesh", key=key)
    r_rs = solve(prob, topo, backend="mesh", key=key,
                 mesh_sync="reduce_scatter")
    np.testing.assert_allclose(np.asarray(r_rs.w), np.asarray(r_ps.w),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(r_rs.alpha),
                               np.asarray(r_ps.alpha), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("sync", ["psum", "reduce_scatter"])
def test_mesh_compressed_matches_host(sync):
    n = len(jax.devices())
    topo = Topology.star(n, 128 // n, rounds=8, local_steps=32)
    X, y = gaussian_regression(m=topo.m_total, d=24)
    prob = Problem.ridge(X, y, lam=LAM)
    key = jax.random.PRNGKey(2)
    sched = Schedule(compression="int8")
    r_h = solve(prob, topo, sched, backend="vmap", key=key)
    r_m = solve(prob, topo, sched, backend="mesh", key=key, mesh_sync=sync)
    np.testing.assert_allclose(np.asarray(r_m.w), np.asarray(r_h.w),
                               rtol=1e-5, atol=1e-6)


def test_mesh_state_floats_sharded_server_saves_memory():
    tree = star(8, 4, outer_rounds=1, local_steps=2)
    plan = compile_tree(tree)
    d = 10_000
    f_ps = mesh_mod.mesh_state_floats(plan, d, sync="psum")
    f_rs = mesh_mod.mesh_state_floats(plan, d, sync="reduce_scatter")
    # replicated: snapshot + server w per level; sharded: one d/K shard
    assert f_rs < f_ps
    assert f_ps - f_rs == 2 * d - -(-d // 8)


def test_mesh_rejects_mixed_specs_within_a_depth():
    kids = tuple(
        TreeNode(name=f"W{k}", rounds=2, data_size=4,
                 up_compress="int8" if k == 0 else "topk_0.5")
        for k in range(2))
    plan = compile_tree(TreeNode(name="root", children=kids, rounds=1))
    with pytest.raises(ValueError, match="ONE compression spec per depth"):
        mesh_mod._comp_specs(plan)


def test_reduce_scatter_refuses_stragglers():
    n = len(jax.devices())
    topo = Topology.star(n, 32 * n, rounds=4, local_steps=8, t_lp=1e-6)
    X, y = gaussian_regression(m=topo.m_total, d=8)
    sess = Session.compile(Problem.ridge(X, y, lam=LAM), topo,
                           backend="mesh", mesh_sync="reduce_scatter")
    from repro.core.delay import StragglerModel
    from repro.runtime.straggler import StragglerPolicy
    pol = StragglerPolicy(model=StragglerModel(slow_prob=0.5,
                                               slow_factor=10.0),
                          max_consecutive=1, seed=0)
    with pytest.raises(ValueError, match="full participation"):
        sess.run(key=jax.random.PRNGKey(0), straggler=pol)


# ---------------------------------------------------------------------------
# API: serialization, schedule knobs, delay-aware auto-selection
# ---------------------------------------------------------------------------
def test_topology_compression_roundtrip_and_filters():
    topo = Topology.two_level(2, 2, 8, root_delay=1e-3, group_delay=1e-5)
    tc = topo.with_compression("int8", min_up_delay=1e-4)
    assert [c.up_compress for c in tc.tree.children] == ["int8", "int8"]
    assert all(l.up_compress == "" for l in tc.tree.leaves())
    t2 = Topology.from_json(tc.to_json())
    assert t2 == tc
    # the override survives into the plan fingerprint via the wire format
    assert compile_tree(t2.tree).fingerprint == \
        compile_tree(tc.tree).fingerprint
    assert compile_tree(tc.tree).has_compression
    with pytest.raises(ValueError):
        topo.with_compression("gzip")


def test_schedule_compression_validation():
    topo = Topology.star(4, 8)
    with pytest.raises(ValueError):
        Schedule(compression="gzip").resolve(topo)
    with pytest.raises(ValueError, match="all 1 internal depths"):
        Schedule(compression=["int8", "int8"]).resolve(topo)
    with pytest.raises(ValueError, match="rounds='auto'"):
        Schedule(compression="auto").resolve(topo)
    r = Schedule(compression="topk_0.1").resolve(topo)
    assert r.compression == ("topk_0.1",)


def test_choose_compression_slow_links_compress_fast_dont():
    """The eq.-(12) trade: a pure-latency level gains nothing on the wire
    (compression only dilutes C -> "none"); a bandwidth-bound slow level
    buys cheaper rounds with a small quality hit -> compressed."""
    levels = [
        FixedLevel("fast", 4, delay_s=1e-4, latency_s=1e-4),  # pure latency
        FixedLevel("slow", 4, delay_s=0.05),                  # all bandwidth
    ]
    rows = choose_compression(levels, C=0.5, delta=0.01, t_total=10.0,
                              t_lp=1e-6)
    assert rows[0]["spec"] == "none"
    assert rows[1]["spec"] != "none"
    # the compressed level's planned delay really is the scaled one
    k, f = comp.parse_spec(rows[1]["spec"])
    assert rows[1]["delay"] == pytest.approx(
        0.05 * comp.wire_ratio(k, f))


def test_schedule_auto_compression_end_to_end():
    topo = Topology.two_level(2, 2, 16, root_delay=5e-2, group_delay=1e-5,
                              local_steps=8)
    # give leaves a compute cost so rounds='auto' is well-posed
    topo = Topology.from_tree(
        Schedule(local_steps=8).resolve(topo).full_tree)
    tree = topo.tree
    import dataclasses as dc

    def with_tlp(node):
        kids = tuple(with_tlp(c) for c in node.children)
        return dc.replace(node, children=kids,
                          t_lp=1e-6 if node.is_leaf else 0.0)
    topo = Topology.from_tree(with_tlp(tree))
    res = Schedule.auto(1.0, C=0.5, compression="auto").resolve(topo)
    assert res.compression is not None and len(res.compression) == 2
    # the planner's per-level rows carry the chosen specs
    assert all("compress" in row for row in res.level_plan)
    # the slow root link (50 ms, bandwidth-bound in the FixedLevel view)
    # must compress
    assert res.compression[0] != "none"
