"""Procedure P (LocalSDCA) behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dual as D
from repro.core.local_sdca import local_sdca
from repro.data.synthetic import gaussian_classification, gaussian_regression


def test_single_worker_converges_to_ridge_optimum():
    X, y = gaussian_regression(m=120, d=20)
    lam = 0.1
    alpha = jnp.zeros((120,))
    w = jnp.zeros((20,))
    da, dw = local_sdca(
        X, y, alpha, w, jax.random.PRNGKey(0),
        loss=D.squared, lam=lam, m_total=120, num_steps=120 * 60,
    )
    alpha, w = alpha + da, w + dw
    a_star = D.ridge_dual_optimum(X, y, lam)
    gap = float(D.duality_gap(alpha, X, y, D.squared, lam))
    gap0 = float(D.duality_gap(jnp.zeros((120,)), X, y, D.squared, lam))
    assert gap < 1e-3 * gap0
    np.testing.assert_allclose(np.asarray(alpha), np.asarray(a_star),
                               rtol=0.05, atol=0.05)


def test_w_consistency():
    """dw returned must equal A_block @ dalpha (Procedure P output spec)."""
    X, y = gaussian_regression(m=50, d=10)
    lam = 0.2
    alpha0 = 0.1 * jax.random.normal(jax.random.PRNGKey(5), (50,))
    w0 = D.w_of_alpha(alpha0, X, lam)
    da, dw = local_sdca(
        X, y, alpha0, w0, jax.random.PRNGKey(1),
        loss=D.squared, lam=lam, m_total=50, num_steps=200,
    )
    np.testing.assert_allclose(
        np.asarray(dw), np.asarray((X.T @ da) / (lam * 50)), rtol=1e-4,
        atol=1e-5,
    )


def test_dual_monotone_nondecreasing():
    X, y = gaussian_regression(m=80, d=12)
    lam = 0.1
    alpha = jnp.zeros((80,))
    w = jnp.zeros((12,))
    prev = float(D.dual_value(alpha, X, y, D.squared, lam))
    for step in range(6):
        da, dw = local_sdca(
            X, y, alpha, w, jax.random.PRNGKey(step),
            loss=D.squared, lam=lam, m_total=80, num_steps=100,
        )
        alpha, w = alpha + da, w + dw
        cur = float(D.dual_value(alpha, X, y, D.squared, lam))
        assert cur >= prev - 1e-6  # exact coordinate maximization never hurts
        prev = cur


def test_svm_hinge_feasible_and_improving():
    X, y = gaussian_classification(m=100, d=15)
    lam = 0.05
    alpha = jnp.zeros((100,))
    w = jnp.zeros((15,))
    d0 = float(D.dual_value(alpha, X, y, D.hinge, lam))
    da, dw = local_sdca(
        X, y, alpha, w, jax.random.PRNGKey(2),
        loss=D.hinge, lam=lam, m_total=100, num_steps=3000,
    )
    alpha, w = alpha + da, w + dw
    # dual feasibility: alpha_i y_i in [0, 1]
    ay = np.asarray(alpha * y)
    assert (ay >= -1e-6).all() and (ay <= 1 + 1e-6).all()
    assert float(D.dual_value(alpha, X, y, D.hinge, lam)) > d0
    # small duality gap on a separable-ish problem
    gap = float(D.duality_gap(alpha, X, y, D.hinge, lam))
    assert gap < 0.1


def test_logistic_newton_steps_improve():
    X, y = gaussian_classification(m=60, d=10)
    lam = 0.1
    alpha = jnp.zeros((60,)) + 0.5 * y  # strictly feasible start
    w = D.w_of_alpha(alpha, X, lam)
    d0 = float(D.dual_value(alpha, X, y, D.logistic, lam))
    da, dw = local_sdca(
        X, y, alpha, w, jax.random.PRNGKey(3),
        loss=D.logistic, lam=lam, m_total=60, num_steps=2000,
    )
    alpha2, w2 = alpha + da, w + dw
    d1 = float(D.dual_value(alpha2, X, y, D.logistic, lam))
    assert d1 > d0
    assert float(D.duality_gap(alpha2, X, y, D.logistic, lam)) < 0.2
