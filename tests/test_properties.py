"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import compression as comp
from repro.core import dual as dual_mod
from repro.core.delay import (log_bound, optimal_h, per_round_factor,
                              rounds_for_budget)
from repro.core.local_sdca import local_sdca
from repro.core.tree import star, two_level
from repro.launch.roofline import (CollectiveOp, collective_summary,
                                   parse_collectives, shape_bytes)

SETTINGS = settings(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# duality invariants
# ---------------------------------------------------------------------------
@SETTINGS
@given(st.integers(4, 24), st.integers(2, 8),
       st.floats(0.01, 1.0), st.integers(0, 10_000))
def test_weak_duality_squared(m, d, lam, seed):
    """P(w(alpha)) >= D(alpha) for any alpha (weak duality)."""
    key = jax.random.PRNGKey(seed)
    kx, ky, ka = jax.random.split(key, 3)
    X = jax.random.normal(kx, (m, d))
    y = jax.random.normal(ky, (m,))
    alpha = jax.random.normal(ka, (m,))
    loss = dual_mod.LOSSES["squared"]
    gap = float(dual_mod.duality_gap(alpha, X, y, loss, lam))
    assert gap >= -1e-4, gap


@SETTINGS
@given(st.integers(8, 32), st.integers(2, 8), st.floats(0.05, 1.0),
       st.integers(0, 10_000), st.integers(1, 64))
def test_sdca_never_decreases_dual(m, d, lam, seed, steps):
    """Every LocalSDCA step is an exact scalar maximization => the dual
    objective is nondecreasing."""
    key = jax.random.PRNGKey(seed)
    kx, ky, kr = jax.random.split(key, 3)
    X = jax.random.normal(kx, (m, d))
    y = jax.random.normal(ky, (m,))
    loss = dual_mod.LOSSES["squared"]
    alpha = jnp.zeros((m,))
    w = jnp.zeros((d,))
    d0 = float(dual_mod.dual_value(alpha, X, y, loss, lam))
    da, dw = local_sdca(X, y, alpha, w, kr, loss=loss, lam=lam,
                        m_total=m, num_steps=steps)
    d1 = float(dual_mod.dual_value(alpha + da, X, y, loss, lam))
    assert d1 >= d0 - 1e-6, (d0, d1)
    # w-consistency: dw == A @ da
    w_expect = dual_mod.w_of_alpha(alpha + da, X, lam)
    np.testing.assert_allclose(np.asarray(w + dw), np.asarray(w_expect),
                               rtol=1e-4, atol=1e-5)


@SETTINGS
@given(st.sampled_from(["squared", "smooth_hinge_1", "logistic"]),
       st.integers(0, 1000))
def test_coord_delta_is_argmax(loss_name, seed):
    """The closed-form coordinate delta maximizes the scalar dual: no
    nearby delta does better."""
    loss = dual_mod.LOSSES[loss_name]
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    wx = float(jax.random.normal(ks[0], ()))
    y = (float(jnp.sign(jax.random.normal(ks[1], ())))
         if loss_name != "squared" else float(jax.random.normal(ks[1], ())))
    alpha = float(jax.random.uniform(ks[2], (), minval=0.1, maxval=0.9)) * (
        y if loss_name != "squared" else 1.0)
    xsq = float(jax.random.uniform(ks[3], (), minval=0.1, maxval=2.0))

    def scalar_dual(delta):
        # the Procedure-P objective, dropping alpha-independent terms:
        # -(xsq/2) d^2 - wx d - l*(-(alpha+d))
        return (-0.5 * xsq * delta**2 - wx * delta
                - loss.conj_neg(jnp.asarray(alpha + delta), jnp.asarray(y)))

    d_star = float(loss.coord_delta(jnp.asarray(wx), jnp.asarray(alpha),
                                    jnp.asarray(y), jnp.asarray(xsq)))
    f_star = float(scalar_dual(d_star))
    for eps in (-0.05, -0.01, 0.01, 0.05):
        trial = d_star + eps
        if loss_name != "squared":
            u = (alpha + trial) * y
            if not (0.0 <= u <= 1.0):
                continue  # outside the dual-feasible set
        assert f_star >= float(scalar_dual(trial)) - 1e-5


# ---------------------------------------------------------------------------
# delay model invariants (paper §6)
# ---------------------------------------------------------------------------
@SETTINGS
@given(st.floats(0.1, 0.9), st.integers(2, 16), st.floats(1e-4, 0.1),
       st.floats(1e-6, 1e-3), st.floats(0.0, 1.0))
def test_bound_is_valid_rate(C, K, delta, t_lp, t_delay):
    """g(H) in (0, 1] and T > 0 => log bound <= 0 (contraction)."""
    g = per_round_factor(16, C, K, delta)
    assert 0.0 < g <= 1.0
    lb = log_bound(16, C=C, K=K, delta=delta, t_total=1.0, t_lp=t_lp,
                   t_delay=t_delay, t_cp=0.0)
    assert lb <= 0.0
    assert rounds_for_budget(1.0, 16, t_lp, t_delay, 0.0) > 0


@SETTINGS
@given(st.floats(0.0, 1e3), st.floats(1.5, 10.0))
def test_optimal_h_monotone_in_delay(r, factor):
    """Paper Fig. 4(b): H*(r2) >= H*(r1) for r2 > r1."""
    kw = dict(C=0.5, K=3, delta=1 / 300, t_total=1.0, t_lp=4e-5, t_cp=3e-5,
              h_max=10**5)
    h1, _ = optimal_h(t_delay=r * 4e-5, **kw)
    h2, _ = optimal_h(t_delay=r * factor * 4e-5 + 1e-6, **kw)
    assert h2 >= h1


# ---------------------------------------------------------------------------
# tree timing invariants
# ---------------------------------------------------------------------------
@SETTINGS
@given(st.integers(2, 8), st.integers(1, 64), st.floats(0, 1e-2),
       st.integers(1, 8))
def test_star_time_matches_eq9(K, H, t_delay, T):
    """star solve_time == eq. (9): (t_lp H + t_delay + t_cp) * T."""
    t_lp, t_cp = 1e-5, 3e-5
    tree = star(K, 10, outer_rounds=T, local_steps=H, t_lp=t_lp,
                t_cp=t_cp, t_delay=t_delay)
    expect = (t_lp * H + t_delay + t_cp) * T
    assert abs(tree.solve_time() - expect) < 1e-12


@SETTINGS
@given(st.integers(2, 4), st.integers(1, 4), st.integers(1, 4),
       st.integers(1, 4))
def test_tree_time_additivity(groups, wpg, gr, rr):
    """Two-level tree time = root rounds x (group phase + root link)."""
    tree = two_level(groups, wpg, 10, root_rounds=rr, group_rounds=gr,
                     local_steps=16, t_lp=1e-5, root_delay=1e-3,
                     group_delay=1e-5)
    per_group_round = 16 * 1e-5 + 1e-5
    per_root_round = gr * per_group_round + 1e-3
    assert abs(tree.solve_time() - rr * per_root_round) < 1e-9


# ---------------------------------------------------------------------------
# compression invariants
# ---------------------------------------------------------------------------
@SETTINGS
@given(st.integers(1, 2048), st.integers(0, 10_000))
def test_int8_quant_bounded_error(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    codes, scale = comp.quantize_int8(x)
    back = comp.dequantize_int8(codes, scale, x.shape, x.dtype)
    blockmax = np.abs(np.asarray(x)).max() if n else 0.0
    # per-block absmax scaling: error <= scale/2 <= blockmax/254
    assert float(jnp.max(jnp.abs(back - x))) <= blockmax / 254.0 + 1e-7


@SETTINGS
@given(st.integers(2, 512), st.floats(0.01, 1.0), st.integers(0, 1000))
def test_topk_preserves_largest(n, frac, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    vals, idx = comp.topk_sparsify(x, frac)
    k = max(int(n * frac), 1)
    thresh = np.sort(np.abs(np.asarray(x)))[-k]
    assert np.all(np.abs(np.asarray(vals)) >= thresh - 1e-6)


# ---------------------------------------------------------------------------
# HLO parsing invariants
# ---------------------------------------------------------------------------
@SETTINGS
@given(st.sampled_from(["f32", "bf16", "s32"]), st.integers(1, 64),
       st.integers(1, 64), st.integers(2, 64))
def test_shape_bytes_and_wire_formulas(dt, a, b, n):
    nbytes = {"f32": 4, "bf16": 2, "s32": 4}[dt]
    assert shape_bytes(f"{dt}[{a},{b}]") == a * b * nbytes
    ar = CollectiveOp("all-reduce", a * b * nbytes, n)
    ag = CollectiveOp("all-gather", a * b * nbytes, n)
    # all-reduce == reduce-scatter + all-gather on the same payload
    rs_plus_ag = 2 * ag.wire_bytes_per_chip()
    assert abs(ar.wire_bytes_per_chip() - rs_plus_ag) < 1e-9


def test_parse_collectives_snippet():
    hlo = """
  %ar = f32[1024,16]{1,0} all-reduce(f32[1024,16]{1,0} %x), replica_groups=[16,16]<=[256], to_apply=%add
  %ag.1 = bf16[64,128]{1,0} all-gather(bf16[4,128]{1,0} %y), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %cp = f32[8]{0} collective-permute(f32[8]{0} %z), source_target_pairs={{0,1},{1,0}}
    """
    ops = parse_collectives(hlo)
    summary = collective_summary(ops)
    assert summary["by_op"]["all-reduce"]["count"] == 1
    assert summary["by_op"]["all-gather"]["count"] == 1
    ar = [o for o in ops if o.op == "all-reduce"][0]
    assert ar.group_size == 16 and ar.result_bytes == 1024 * 16 * 4
    ag = [o for o in ops if o.op == "all-gather"][0]
    assert ag.group_size == 4
